"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=jnp.float32) -> jnp.ndarray:
    return jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)


def two_sided_rotate_ref(x, U, V, transpose: bool = True):
    """transpose=True: U^T x V (into rotated space);
    transpose=False: U x V^T (back to original space). U/V may be None."""
    x = x.astype(jnp.float32)
    if U is not None:
        Uf = U.astype(jnp.float32)
        x = jnp.einsum("...ji,...jk->...ik", Uf, x) if transpose else jnp.einsum(
            "...ij,...jk->...ik", Uf, x
        )
    if V is not None:
        Vf = V.astype(jnp.float32)
        x = jnp.einsum("...ij,...jk->...ik", x, Vf) if transpose else jnp.einsum(
            "...ik,...jk->...ij", x, Vf
        )
    return x


def fused_adam_scale_ref(g, m, v, beta2, eps, bc1, bc2):
    g = g.astype(jnp.float32)
    m = m.astype(jnp.float32)
    v = v.astype(jnp.float32)
    v_new = beta2 * v + (1.0 - beta2) * g * g
    step = (m / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    return step, v_new


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None, scale=None):
    """O = softmax(QK^T * scale + mask) V. q,k,v: (B,H,S,dh)."""
    B, H, S, dh = q.shape
    scale = scale if scale is not None else 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= j > i - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
