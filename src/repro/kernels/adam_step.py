"""Fused Adam-moment/step Pallas kernel.

The elementwise half of the basis-rotation update — second-moment EMA,
bias correction, rsqrt and step — reads/writes each of (g~, v, m~) exactly
once when fused, instead of one HBM round-trip per op. On TPU this is a
VPU-bound elementwise kernel tiled over (block_r, block_c) VMEM blocks.

Computes (in fp32):
    v'   = b2 * v + (1 - b2) * g~^2
    step = (m~ / bc1) / (sqrt(v' / bc2) + eps)
returning (step, v'). Scalars (b2, eps, bc1, bc2) arrive via a (1, 4) SMEM
operand so the kernel is reusable across training steps without recompiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _adam_kernel(scalars_ref, g_ref, m_ref, v_ref, step_ref, v_out_ref):
    b2 = scalars_ref[0, 0]
    eps = scalars_ref[0, 1]
    bc1 = scalars_ref[0, 2]
    bc2 = scalars_ref[0, 3]
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    v_new = b2 * v + (1.0 - b2) * g * g
    # denominator matches the reference exactly: sqrt(v/bc2) + eps (an rsqrt
    # would fold eps inside the root and diverge from ref.py near v ~ 0)
    step = (m / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    step_ref[...] = step.astype(step_ref.dtype)
    v_out_ref[...] = v_new.astype(v_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_c", "interpret")
)
def fused_adam_scale(
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    beta2: jnp.ndarray,
    eps: jnp.ndarray,
    bc1: jnp.ndarray,
    bc2: jnp.ndarray,
    *,
    block_r: int = 256,
    block_c: int = 256,
    interpret: bool = True,
):
    """Returns (step_dir, v_new) for 2-D inputs (leading dims: vmap)."""
    R, C = g.shape
    br, bc = min(block_r, R), min(block_c, C)
    pr, pc = (-R) % br, (-C) % bc
    if pr or pc:
        pad = lambda x: jnp.pad(x, ((0, pr), (0, pc)))
        g, m, v = pad(g), pad(m), pad(v)
    Rp, Cp = g.shape
    scalars = jnp.stack(
        [jnp.asarray(beta2, jnp.float32), jnp.asarray(eps, jnp.float32),
         jnp.asarray(bc1, jnp.float32), jnp.asarray(bc2, jnp.float32)]
    )[None, :]

    scalar_spec = pl.BlockSpec((1, 4), lambda i, j: (0, 0))
    if pltpu is not None and not interpret:
        scalar_spec = pl.BlockSpec((1, 4), lambda i, j: (0, 0), memory_space=pltpu.SMEM)

    step, v_new = pl.pallas_call(
        _adam_kernel,
        grid=(Rp // br, Cp // bc),
        in_specs=[
            scalar_spec,
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, Cp), jnp.float32),
            jax.ShapeDtypeStruct((Rp, Cp), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, g, m, v)
    return step[:R, :C], v_new[:R, :C]
