"""Jit'd public wrappers over the Pallas kernels.

`interpret` defaults to True off-TPU (the kernel body runs in Python on CPU
for correctness validation) and False on TPU (compiled Mosaic).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.adam_step import fused_adam_scale
from repro.kernels.flash import flash_attention
from repro.kernels.matmul import matmul


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tuned_blocks(kernel: str, shape, dtype, keys) -> dict:
    """Tuned tile plan from the autotuner cache (`repro.tune`), restricted
    to the kernel's block kwargs; {} on a cache miss so the kernel's static
    defaults apply."""
    from repro.tune import kernel_plan

    plan = kernel_plan(kernel, shape, str(jnp.dtype(dtype)))
    if not plan:
        return {}
    return {k: int(plan[k]) for k in keys if k in plan}


def pallas_matmul(a, b, **kw):
    kw.setdefault("interpret", default_interpret())
    if not kw["interpret"]:
        for k, v in _tuned_blocks(
            "matmul", (a.shape[0], b.shape[1], a.shape[1]), a.dtype,
            ("block_m", "block_n", "block_k"),
        ).items():
            kw.setdefault(k, v)
    return matmul(a, b, **kw)


def _interp_blocks(*dims):
    """Full-operand block sizes for interpret mode: the interpreter pays a
    large per-grid-step overhead (slice/mask/trace per tile), so off-TPU one
    grid step over the whole (small, CPU-scale) operand is ~30x faster than
    MXU-shaped 128-tiles. Compiled Mosaic keeps the 128 tiling."""
    return {k: max(d, 1) for k, d in dims}


def _rotate2d(x, U, V, transpose: bool, interpret: bool):
    x = x.astype(jnp.float32)

    def mm(a, b):
        kw = (
            _interp_blocks(("block_m", a.shape[0]), ("block_n", b.shape[1]),
                           ("block_k", a.shape[1]))
            if interpret else _tuned_blocks(
                "matmul", (a.shape[0], b.shape[1], a.shape[1]), a.dtype,
                ("block_m", "block_n", "block_k"),
            )
        )
        return matmul(a, b, interpret=interpret, **kw)

    if U is not None:
        Uf = U.astype(jnp.float32)
        x = mm(Uf.T if transpose else Uf, x)
    if V is not None:
        Vf = V.astype(jnp.float32)
        x = mm(x, Vf if transpose else Vf.T)
    return x


def two_sided_rotate(x, U=None, V=None, *, transpose: bool = True,
                     interpret: Optional[bool] = None):
    """U^T x V (transpose=True) or U x V^T (transpose=False).

    Supports arbitrary leading batch dims (vmapped over them); U/V may be
    None for unilateral rotation.
    """
    interpret = default_interpret() if interpret is None else interpret
    nbatch = x.ndim - 2
    fn = functools.partial(_rotate2d, transpose=transpose, interpret=interpret)
    for _ in range(nbatch):
        fn = jax.vmap(fn)
    return fn(x, U, V)


def adam_scale(g, m, v, beta2, eps, bc1, bc2, *, interpret: Optional[bool] = None):
    """Fused (step_dir, v_new); arbitrary leading batch dims."""
    interpret = default_interpret() if interpret is None else interpret
    kw = (
        _interp_blocks(("block_r", g.shape[-2] if g.ndim >= 2 else 1),
                       ("block_c", g.shape[-1]))
        if interpret else _tuned_blocks(
            "adam_scale",
            (g.shape[-2] if g.ndim >= 2 else 1, g.shape[-1]), g.dtype,
            ("block_r", "block_c"),
        )
    )
    fn = functools.partial(fused_adam_scale, interpret=interpret, **kw)
    nbatch = g.ndim - 2
    if g.ndim == 1:
        s, vn = fn(g[None, :], m[None, :], v[None, :], beta2, eps, bc1, bc2)
        return s[0], vn[0]
    f = fn
    for _ in range(nbatch):
        f = jax.vmap(f, in_axes=(0, 0, 0, None, None, None, None))
    return f(g, m, v, beta2, eps, bc1, bc2)


def attention(q, k, v, *, causal=True, window=None, interpret: Optional[bool] = None,
              block_q: Optional[int] = None, block_k: Optional[int] = None):
    """Blocks default to the autotuned plan (`repro.tune`) for this
    (S, dh, dtype, platform); see `flash._plan` for the fallback ladder."""
    interpret = default_interpret() if interpret is None else interpret
    return flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
