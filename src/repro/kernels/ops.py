"""Jit'd public wrappers over the Pallas kernels.

`interpret` defaults to True off-TPU (the kernel body runs in Python on CPU
for correctness validation) and False on TPU (compiled Mosaic).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.adam_step import fused_adam_scale
from repro.kernels.flash import flash_attention
from repro.kernels.matmul import matmul


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pallas_matmul(a, b, **kw):
    kw.setdefault("interpret", default_interpret())
    return matmul(a, b, **kw)


def _rotate2d(x, U, V, transpose: bool, interpret: bool):
    x = x.astype(jnp.float32)
    if U is not None:
        Uf = U.astype(jnp.float32)
        x = matmul(Uf.T if transpose else Uf, x, interpret=interpret)
    if V is not None:
        Vf = V.astype(jnp.float32)
        x = matmul(x, Vf if transpose else Vf.T, interpret=interpret)
    return x


def two_sided_rotate(x, U=None, V=None, *, transpose: bool = True,
                     interpret: Optional[bool] = None):
    """U^T x V (transpose=True) or U x V^T (transpose=False).

    Supports arbitrary leading batch dims (vmapped over them); U/V may be
    None for unilateral rotation.
    """
    interpret = default_interpret() if interpret is None else interpret
    nbatch = x.ndim - 2
    fn = functools.partial(_rotate2d, transpose=transpose, interpret=interpret)
    for _ in range(nbatch):
        fn = jax.vmap(fn)
    return fn(x, U, V)


def adam_scale(g, m, v, beta2, eps, bc1, bc2, *, interpret: Optional[bool] = None):
    """Fused (step_dir, v_new); arbitrary leading batch dims."""
    interpret = default_interpret() if interpret is None else interpret
    fn = functools.partial(fused_adam_scale, interpret=interpret)
    nbatch = g.ndim - 2
    if g.ndim == 1:
        s, vn = fn(g[None, :], m[None, :], v[None, :], beta2, eps, bc1, bc2)
        return s[0], vn[0]
    f = fn
    for _ in range(nbatch):
        f = jax.vmap(f, in_axes=(0, 0, 0, None, None, None, None))
    return f(g, m, v, beta2, eps, bc1, bc2)


def attention(q, k, v, *, causal=True, window=None, interpret: Optional[bool] = None,
              block_q: int = 128, block_k: int = 128):
    interpret = default_interpret() if interpret is None else interpret
    return flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
