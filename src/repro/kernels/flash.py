"""Flash-attention Pallas kernel (online softmax), causal + sliding window.

The attention score matrix is never materialised in HBM: the kernel streams
K/V blocks against each Q block, carrying the running row-max m, normaliser l
and output accumulator in VMEM scratch — the TPU-fused version of the
chunked-attention schedule used by the pure-JAX model path
(`repro.models.attention`). BlockSpecs are 128-aligned for the MXU.

Layout: inputs are (BH, S, dh) with batch*heads flattened into the leading
grid dimension; grid = (BH, S/bq, S/bk) with the K dimension innermost.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, bq: int, bk: int, k_steps: int, causal: bool, window
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == k_steps - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q,k,v: (B, H, S, dh) -> (B, H, S, dh). S must divide by the blocks."""
    B, H, S, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, "seq must divide block sizes"
    BH = B * H
    qf = q.reshape(BH, S, dh)
    kf = k.reshape(BH, S, dh)
    vf = v.reshape(BH, S, dh)
    k_steps = S // bk

    try:
        from jax.experimental.pallas import tpu as pltpu

        scratch = [
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ]
    except Exception:  # pragma: no cover
        scratch = [
            jax.ShapeDtypeStruct((bq, 1), jnp.float32),
            jax.ShapeDtypeStruct((bq, 1), jnp.float32),
            jax.ShapeDtypeStruct((bq, dh), jnp.float32),
        ]

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, bq=bq, bk=bk,
            k_steps=k_steps, causal=causal, window=window,
        ),
        grid=(BH, S // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, dh)
