"""Flash-attention Pallas kernels (online softmax), causal + sliding window.

The attention score matrix is never materialised in HBM: the forward kernel
streams K/V blocks against each Q block, carrying the running row-max m,
normaliser l and output accumulator in VMEM scratch — the TPU-fused version
of the chunked-attention schedule used by the pure-JAX model path
(`repro.models.attention`). BlockSpecs are 128-aligned for the MXU.

The public `flash_attention` is differentiable end to end via
`jax.custom_vjp`: the forward additionally emits the per-row logsumexp
L = m + log l, and the backward is a recompute-style pair of Pallas kernels
(FlashAttention-2 style) that rebuild p = exp(s·scale − L) tile by tile:

* dQ kernel: grid (BH, S/bq, S/bk), K innermost, (bq, dh) f32 accumulator;
  dS = p ⊙ (dO·Vᵀ − D) with D = rowsum(dO ⊙ O), dQ = scale · dS·K.
* dK/dV kernel: grid (BH, S/bk, S/bq), Q innermost, (bk, dh) accumulators;
  dV = pᵀ·dO, dK = scale · dSᵀ·Q.

Row statistics never hit HBM unnormalised: only O and L are saved, so the
residual cost is O(S·dh + S) per head — what the 1F1B input stash budget
assumes (DESIGN.md §9).

Masking: fully-masked tiles are guarded (p forced to 0) so they contribute
nothing to l/acc; fully-masked *rows* produce exactly-zero output and an
L sentinel of NEG_INF. Sequence lengths that do not divide the block sizes
are zero-padded up front and the kernels mask `cols < seq_len`; the padding
is applied with differentiable jnp ops outside the custom_vjp, so cotangents
for padded rows arrive as zeros and contribute nothing to dK/dV.

Layout: inputs are (BH, S, dh) with batch*heads flattened into the leading
grid dimension; grid = (BH, S/bq, S/bk) with the K dimension innermost.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
_EPS = 1e-30


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _scratch(shapes):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return [pltpu.VMEM(s, jnp.float32) for s in shapes]
    except Exception:  # pragma: no cover
        return [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]


def _tile_mask(iq, ik, bq: int, bk: int, *, causal: bool, window, seq_len: int):
    """Validity mask for the (iq, ik) tile; padded key columns are invalid."""
    rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = cols < seq_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return mask


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, L_ref, m_ref, l_ref, acc_ref,
    *, scale: float, bq: int, bk: int, k_steps: int, causal: bool, window,
    seq_len: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    mask = _tile_mask(iq, ik, bq, bk, causal=causal, window=window,
                      seq_len=seq_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # guard: in a fully-masked tile m_new can stay ~NEG_INF, making
    # exp(s - m_new) = exp(0) = 1 for every masked entry — without the mask
    # here those 1s pollute l/acc (mean-of-V garbage for fully-masked rows)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == k_steps - 1)
    def _done():
        l = l_ref[...]
        o = jnp.where(l > 0.0, acc_ref[...] / jnp.maximum(l, _EPS), 0.0)
        o_ref[0] = o.astype(o_ref.dtype)
        lse = jnp.where(
            l > 0.0, m_ref[...] + jnp.log(jnp.maximum(l, _EPS)), NEG_INF
        )
        L_ref[0] = lse[:, 0]


def _flash_forward(cfg, qf, kf, vf):
    """Padded-layout forward: (BH, Sp, dh)³ -> (O (BH,Sp,dh), L (BH,Sp))."""
    causal, window, bq, bk, seq_len, interpret = cfg
    BH, Sp, dh = qf.shape
    scale = 1.0 / math.sqrt(dh)
    k_steps = Sp // bk
    return pl.pallas_call(
        functools.partial(
            _flash_fwd_kernel, scale=scale, bq=bq, bk=bk, k_steps=k_steps,
            causal=causal, window=window, seq_len=seq_len,
        ),
        grid=(BH, Sp // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sp, dh), qf.dtype),
            jax.ShapeDtypeStruct((BH, Sp), jnp.float32),
        ],
        scratch_shapes=_scratch([(bq, 1), (bq, 1), (bq, dh)]),
        interpret=interpret,
    )(qf, kf, vf)


# ---------------------------------------------------------------------------
# Backward (recompute from saved O and logsumexp L)
# ---------------------------------------------------------------------------


def _flash_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, L_ref, D_ref, dq_ref, acc_ref,
    *, scale: float, bq: int, bk: int, k_steps: int, causal: bool, window,
    seq_len: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    L = L_ref[0]  # (bq,) f32
    D = D_ref[0]  # (bq,) f32

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    mask = _tile_mask(iq, ik, bq, bk, causal=causal, window=window,
                      seq_len=seq_len)
    # fully-masked rows carry L = NEG_INF; exp overflows there but the mask
    # zeroes every such entry before it can propagate
    p = jnp.where(mask, jnp.exp(s - L[:, None]), 0.0)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - D[:, None])
    acc_ref[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale

    @pl.when(ik == k_steps - 1)
    def _done():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, L_ref, D_ref, dk_ref, dv_ref,
    dk_acc, dv_acc,
    *, scale: float, bq: int, bk: int, q_steps: int, causal: bool, window,
    seq_len: int,
):
    ik = pl.program_id(1)
    iq = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    L = L_ref[0]
    D = D_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    mask = _tile_mask(iq, ik, bq, bk, causal=causal, window=window,
                      seq_len=seq_len)
    p = jnp.where(mask, jnp.exp(s - L[:, None]), 0.0)  # (bq, bk)
    dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - D[:, None])
    dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale

    @pl.when(iq == q_steps - 1)
    def _done():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _flash_backward(cfg, qf, kf, vf, o, L, do):
    """Padded-layout backward -> (dQ, dK, dV), each (BH, Sp, dh)."""
    causal, window, bq, bk, seq_len, interpret = cfg
    BH, Sp, dh = qf.shape
    scale = 1.0 / math.sqrt(dh)
    q_steps, k_steps = Sp // bq, Sp // bk
    # D_i = rowsum(dO ⊙ O) — cheap elementwise reduce, plain XLA
    D = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    q_spec = pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0))
    kv_spec = pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0))
    row_spec = pl.BlockSpec((1, bq), lambda b, i, j: (b, i))
    dq = pl.pallas_call(
        functools.partial(
            _flash_bwd_dq_kernel, scale=scale, bq=bq, bk=bk, k_steps=k_steps,
            causal=causal, window=window, seq_len=seq_len,
        ),
        grid=(BH, q_steps, k_steps),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Sp, dh), qf.dtype),
        scratch_shapes=_scratch([(bq, dh)]),
        interpret=interpret,
    )(qf, kf, vf, do, L, D)

    # transposed grid: K blocks outer, Q innermost, accumulate over queries
    q_spec_t = pl.BlockSpec((1, bq, dh), lambda b, j, i: (b, i, 0))
    kv_spec_t = pl.BlockSpec((1, bk, dh), lambda b, j, i: (b, j, 0))
    row_spec_t = pl.BlockSpec((1, bq), lambda b, j, i: (b, i))
    dk, dv = pl.pallas_call(
        functools.partial(
            _flash_bwd_dkv_kernel, scale=scale, bq=bq, bk=bk, q_steps=q_steps,
            causal=causal, window=window, seq_len=seq_len,
        ),
        grid=(BH, k_steps, q_steps),
        in_specs=[q_spec_t, kv_spec_t, kv_spec_t, q_spec_t, row_spec_t,
                  row_spec_t],
        out_specs=[kv_spec_t, kv_spec_t],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sp, dh), kf.dtype),
            jax.ShapeDtypeStruct((BH, Sp, dh), vf.dtype),
        ],
        scratch_shapes=_scratch([(bk, dh), (bk, dh)]),
        interpret=interpret,
    )(qf, kf, vf, do, L, D)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(cfg, qf, kf, vf):
    o, _ = _flash_forward(cfg, qf, kf, vf)
    return o


def _flash_fwd_rule(cfg, qf, kf, vf):
    o, L = _flash_forward(cfg, qf, kf, vf)
    return o, (qf, kf, vf, o, L)


def _flash_bwd_rule(cfg, res, do):
    qf, kf, vf, o, L = res
    return _flash_backward(cfg, qf, kf, vf, o, L, do)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


def _plan(
    S: int,
    block_q=None,
    block_k=None,
    *,
    dh: int = 0,
    dtype_name: str = "float32",
    interpret: bool = True,
):
    """Resolve block sizes and the padded sequence length.

    Explicit caller blocks always win. When a block is None, the tuned-plan
    cache (`repro.tune.kernel_plan`, keyed by (kernel, shape, dtype,
    platform)) is consulted at trace time; on a cache miss the default is
    one full-operand tile in interpret mode (one grid step — the
    interpreter pays per grid step, so fewer steps dominate on CPU) and the
    128-aligned MXU tile compiled.
    """
    cap = max(8, _next_pow2(S))
    if block_q is None or block_k is None:
        plan = None
        if dh:
            from repro.tune import kernel_plan

            plan = kernel_plan("flash", (S, dh), dtype_name)
        default = cap if interpret else 128
        if block_q is None:
            block_q = int(plan["block_q"]) if plan else default
        if block_k is None:
            block_k = int(plan["block_k"]) if plan else default
    assert block_q & (block_q - 1) == 0 and block_k & (block_k - 1) == 0, \
        "block sizes must be powers of two"
    bq, bk = min(block_q, cap), min(block_k, cap)
    return bq, bk, _round_up(S, max(bq, bk))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,
    block_q=None,
    block_k=None,
    interpret: bool = True,
) -> jnp.ndarray:
    """q,k,v: (B, H, S, dh) -> (B, H, S, dh) in q.dtype; differentiable.

    S need not divide the block sizes: inputs are zero-padded to the block
    grid and the pad is sliced back off (padded key columns are masked
    inside the kernels, so numerics are unaffected). ``block_q``/``block_k``
    default to the autotuned plan for this (S, dh, dtype, platform) — see
    `_plan`.
    """
    B, H, S, dh = q.shape
    bq, bk, Sp = _plan(
        S, block_q, block_k, dh=dh, dtype_name=str(q.dtype),
        interpret=interpret,
    )
    BH = B * H
    qf = q.reshape(BH, S, dh)
    kf = k.reshape(BH, S, dh)
    vf = v.reshape(BH, S, dh)
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0))
        qf, kf, vf = jnp.pad(qf, pad), jnp.pad(kf, pad), jnp.pad(vf, pad)
    cfg = (causal, window, bq, bk, S, interpret)
    o = _flash(cfg, qf, kf, vf)
    return o[:, :S].reshape(B, H, S, dh)
