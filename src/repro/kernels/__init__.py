# Pallas TPU kernels for the perf-critical layers:
#  - matmul.py     : tiled MXU matmul (basis-rotation rotations)
#  - adam_step.py  : fused second-moment EMA + bias-corrected step
#  - flash.py      : flash attention (online softmax, causal/windowed)
# ops.py holds the jit'd wrappers; ref.py the pure-jnp oracles.
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
