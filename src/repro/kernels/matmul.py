"""Tiled matmul Pallas kernel — the basis-rotation hot spot.

Every basis-rotation step performs two two-sided rotations per weight matrix
(U^T G V and U X V^T), i.e. four (m x m)(m x n)-class matmuls. On TPU these
are MXU work; the kernel tiles all three dims with 128-aligned BlockSpecs so
each (block_m x block_k) x (block_k x block_n) product fits VMEM, accumulates
in an fp32 VMEM scratch across the k grid dimension, and writes the output
tile once on the last k step.

Grid: (m / bm, n / bn, k / bk) with k innermost ("arbitrary" semantics — the
accumulator carries across k steps; m/n are parallel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; absent on CPU-only installs is fine
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "out_dtype"),
)
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """C = A @ B via the tiled Pallas kernel. a: (M,K), b: (K,N).

    Inputs are zero-padded up to tile multiples and the result sliced back,
    so arbitrary shapes are accepted; MXU-aligned shapes take the fast path.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, f"contraction mismatch {a.shape} x {b.shape}"
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    a_p = _pad_to(a, bm, bk)
    b_p = _pad_to(b, bk, bn)
    Mp, Kp = a_p.shape
    Np = b_p.shape[1]
    k_steps = Kp // bk

    scratch = (
        [pltpu.VMEM((bm, bn), jnp.float32)]
        if (pltpu is not None and not interpret)
        else [pl.BlockSpec(memory_space=None)]
    )
    # In interpret mode scratch_shapes still needs concrete ShapeDtypeStructs.
    scratch = [jax.ShapeDtypeStruct((bm, bn), jnp.float32)]
    if pltpu is not None:
        scratch = [pltpu.VMEM((bm, bn), jnp.float32)]

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(Mp // bm, Np // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]
