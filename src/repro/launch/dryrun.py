from repro.launch.devices import ensure_host_devices

ensure_host_devices(512)

"""Multi-pod dry-run: AOT lower + compile every (architecture x input shape)
on the production meshes, proving the distribution config is coherent without
hardware, and extract the roofline terms from the compiled artifact.

MUST be imported before any other jax-touching module sets device state —
hence the `ensure_host_devices` call above everything else (it appends to
XLA_FLAGS without clobbering user flags and defers to accelerators).

Usage:
    python -m repro.launch.dryrun --arch qwen3_0_6b --shape train_4k
    python -m repro.launch.dryrun --all --out experiments/dryrun.jsonl
    python -m repro.launch.dryrun --arch jamba_v0_1_52b --shape long_500k --multi-pod
"""
import argparse  # noqa: E402
import json  # noqa: E402
import os  # noqa: E402
import time  # noqa: E402
from functools import partial  # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, INPUT_SHAPES, InputShape, ModelConfig, OptimizerConfig, get_config  # noqa: E402
from repro.configs.catalog import shapes_for  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_shape_dict, use_mesh  # noqa: E402
from repro.launch.roofline import Roofline, roofline_from_compiled  # noqa: E402
from repro.models.model import forward_decode, forward_train, init_cache, init_model, loss_fn  # noqa: E402
from repro.optim.base import apply_updates, clip_by_global_norm  # noqa: E402
from repro.optim.factory import build_optimizer  # noqa: E402
from repro.sharding.rules import (  # noqa: E402
    cache_pspecs,
    make_shardings,
    opt_state_pspecs,
    params_pspecs,
    tokens_pspec,
)

# Big architectures use the paper's memory-efficient estimation strategy
# (S=1st, G=unilateral; Appendix H) in the production dry-run; the rest use
# the paper default (2nd/bilateral).
BIG_ARCHS = {"llava_next_34b", "mixtral_8x22b", "jamba_v0_1_52b", "deepseek_v2_236b"}


def rotation_strategy(arch: str) -> Tuple[str, str]:
    return ("1st", "unilateral") if arch in BIG_ARCHS else ("2nd", "bilateral")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ModelConfig, shape: InputShape, mesh) -> Dict:
    """Model inputs for one input-shape as sharded ShapeDtypeStructs."""
    ms = mesh_shape_dict(mesh)
    B, S = shape.global_batch, shape.seq_len
    tok_sh = make_shardings(tokens_pspec(B, ms, extra_dims=1), mesh)
    if shape.mode in ("train", "prefill"):
        n_front = cfg.frontend_tokens if cfg.frontend == "vision" else 0
        S_text = S - n_front
        assert S_text > 0
        if cfg.num_codebooks > 1:
            tok_sh3 = make_shardings(tokens_pspec(B, ms, extra_dims=2), mesh)
            batch = {
                "tokens": sds((B, S_text, cfg.num_codebooks), jnp.int32, tok_sh3),
                "labels": sds((B, S_text, cfg.num_codebooks), jnp.int32, tok_sh3),
            }
        else:
            batch = {
                "tokens": sds((B, S_text), jnp.int32, tok_sh),
                "labels": sds((B, S_text), jnp.int32, tok_sh),
            }
        if n_front:
            fr_sh = make_shardings(tokens_pspec(B, ms, extra_dims=2), mesh)
            batch["frontend"] = sds(
                (B, n_front, cfg.frontend_dim), jnp.float32, fr_sh
            )
        return batch
    # decode: one token + full cache
    if cfg.num_codebooks > 1:
        tok = sds((B, 1, cfg.num_codebooks), jnp.int32)
    else:
        tok = sds((B, 1), jnp.int32)
    cache_shapes = jax.eval_shape(partial(init_cache, cfg, B, S))
    c_specs = cache_pspecs(cache_shapes, ms, stacked=cfg.scan_layers)
    c_sh = make_shardings(c_specs, mesh)
    cache = jax.tree.map(
        lambda a, s: sds(a.shape, a.dtype, s), cache_shapes, c_sh,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    return {"token": tok, "cache": cache, "pos": sds((), jnp.int32)}


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt, grad_specs=None, microbatches: int = 1):
    def grad_of(params, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)
        return loss, grads

    def train_step(params, opt_state, batch, step):
        if microbatches > 1:
            # gradient accumulation: activations live one microbatch at a
            # time; grads accumulate in a single fp32 buffer
            B = jax.tree_util.tree_leaves(batch)[0].shape[0]
            assert B % microbatches == 0
            mb = jax.tree.map(
                lambda x: x.reshape(microbatches, B // microbatches, *x.shape[1:]),
                batch,
            )

            def body(carry, mbatch):
                acc_loss, acc_g = carry
                loss, grads = grad_of(params, mbatch)
                acc_g = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    acc_g, grads,
                )
                return (acc_loss + loss / microbatches, acc_g), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mb)
        else:
            loss, grads = grad_of(params, batch)
        if grad_specs is not None:
            # pin gradient shardings to the parameter layout so the data-axis
            # reduction lowers as reduce-scatter (ZeRO) instead of all-reduce
            grads = jax.lax.with_sharding_constraint(grads, grad_specs)
        grads = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _ = forward_train(params, cfg, batch["tokens"], batch.get("frontend"))
        return logits[:, -1]  # next-token logits

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, token, cache, pos):
        logits, cache = forward_decode(params, cfg, token, cache, pos)
        return logits, cache

    return serve_step


# ---------------------------------------------------------------------------
# FLOPs accounting
# ---------------------------------------------------------------------------


def param_counts(params_shapes, cfg: ModelConfig) -> Tuple[int, int]:
    """(total, active) parameter counts from shapes (active: MoE top-k)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params_shapes)
    total = active = 0
    for path, x in flat:
        n = 1
        for d in x.shape:
            n *= d
        total += n
        keyname = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if keyname.endswith("_e") and cfg.moe is not None:
            active += n * cfg.moe.top_k // cfg.moe.num_experts
        else:
            active += n
    return total, active


def model_flops(cfg: ModelConfig, shape: InputShape, n_active: int) -> float:
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


# ---------------------------------------------------------------------------
# Dry-run driver
# ---------------------------------------------------------------------------


def _compile(cfg: ModelConfig, shape: InputShape, mesh, optimizer, rotation, arch,
             grad_rs: bool = False, microbatches: int = 1):
    """Lower + compile one (config x shape) on mesh. Returns compiled exe."""
    ms = mesh_shape_dict(mesh)
    params_shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    p_specs = params_pspecs(params_shapes, ms)
    p_sh = make_shardings(p_specs, mesh)
    params_in = jax.tree.map(
        lambda a, s: sds(a.shape, a.dtype, s), params_shapes, p_sh,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    batch = input_specs(cfg, shape, mesh)

    with use_mesh(mesh):
        if shape.mode == "train":
            src, geom = rotation or rotation_strategy(arch)
            ocfg = OptimizerConfig(
                name=optimizer, rotation_source=src, rotation_geometry=geom,
                rotation_freq=10, total_steps=10_000,
            )
            opt = build_optimizer(ocfg, params_shapes, cfg, num_stages=1, apply_delay=False)
            o_shapes = jax.eval_shape(opt.init, params_shapes)
            o_specs = opt_state_pspecs(o_shapes, params_shapes, ms)
            o_sh = make_shardings(o_specs, mesh)
            o_in = jax.tree.map(
                lambda a, s: sds(a.shape, a.dtype, s), o_shapes, o_sh,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
            fn = jax.jit(
                make_train_step(cfg, opt, p_specs if grad_rs else None, microbatches),
                out_shardings=(p_sh, o_sh, None),
            )
            lowered = fn.lower(params_in, o_in, batch, sds((), jnp.int32))
        elif shape.mode == "prefill":
            fn = jax.jit(make_prefill_step(cfg))
            lowered = fn.lower(params_in, batch)
        else:  # decode
            fn = jax.jit(make_serve_step(cfg))
            lowered = fn.lower(params_in, batch["token"], batch["cache"], batch["pos"])
        return lowered.compile()


def _cost_triplet(compiled):
    from repro.launch.roofline import collective_stats

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    st = collective_stats(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(st.total_bytes),
        dict(st.bytes_by_op),
    )


def dryrun_one(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    optimizer: str = "basis_rotation",
    rotation: Optional[Tuple[str, str]] = None,
    verbose: bool = True,
    overrides: Optional[Dict] = None,
    grad_rs: bool = False,
    variant: str = "",
    extrapolate: bool = True,
    microbatches: int = 1,
) -> Dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = INPUT_SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.supports_long_context():
        row = {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "skipped", "reason": "full attention (DESIGN.md §6)",
        }
        if verbose:
            print(json.dumps(row))
        return row
    mesh = make_production_mesh(multi_pod=multi_pod)
    ms = mesh_shape_dict(mesh)
    t0 = time.time()

    params_shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    n_total, n_active = param_counts(params_shapes, cfg)

    # (A) full-depth compile in compact scan mode: THE lower+compile proof
    #     and the per-device memory analysis (loops reuse buffers).
    compiled_full = _compile(cfg, shape, mesh, optimizer, rotation, arch, grad_rs,
                             microbatches)
    t_full = time.time() - t0

    # (B,C) 1- and 2-superblock unrolled compiles: XLA cost_analysis counts a
    #       while-loop body once, so per-layer costs are extrapolated from
    #       straight-line HLO: total = c1 + (n_super - 1) * (c2 - c1).
    #       (skipped for the multi-pod pass: only the compile proof + memory
    #       analysis are required there; the roofline table is single-pod)
    P = len(cfg.pattern)
    n_super = cfg.num_superblocks
    cfg1 = cfg.replace(num_layers=P, scan_unroll=True)
    cfg2 = cfg.replace(num_layers=2 * P, scan_unroll=True)
    if not extrapolate:
        f1 = b1 = cb1 = 0.0
        coll1 = {}
    else:
        f1, b1, cb1, coll1 = _cost_triplet(
            _compile(cfg1, shape, mesh, optimizer, rotation, arch, grad_rs, microbatches))
    if not extrapolate:
        flops, hbm, coll, coll_by_op = 0.0, 0.0, 0.0, {}
    elif n_super > 1:
        f2, b2, cb2, coll2 = _cost_triplet(
            _compile(cfg2, shape, mesh, optimizer, rotation, arch, grad_rs, microbatches))
        flops = max(f1, f1 + (n_super - 1) * (f2 - f1))
        hbm = max(b1, b1 + (n_super - 1) * (b2 - b1))
        coll = max(0.0, cb1 + (n_super - 1) * (cb2 - cb1))
        coll_by_op = {
            k: max(0, int(coll1.get(k, 0) + (n_super - 1) * (coll2.get(k, 0) - coll1.get(k, 0))))
            for k in set(coll1) | set(coll2)
        }
    else:
        flops, hbm, coll, coll_by_op = f1, b1, cb1, coll1
    t_extrap = time.time() - t0 - t_full

    n_chips = 1
    for v in ms.values():
        n_chips *= v
    mf = model_flops(cfg, shape, n_active) / n_chips  # per-chip MODEL_FLOPS

    from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    compute_s, memory_s, coll_s = flops / PEAK_FLOPS, hbm / HBM_BW, coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    mem = compiled_full.memory_analysis()
    row = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "variant": variant,
        "status": "ok",
        "mesh": dict(ms),
        "mode": shape.mode,
        "params_total": n_total,
        "params_active": n_active,
        "compile_s": round(t_full, 1),
        "extrap_s": round(t_extrap, 1),
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": coll,
        "compute_s": round(compute_s, 6),
        "memory_s": round(memory_s, 6),
        "collective_s": round(coll_s, 6),
        "bottleneck": max(terms, key=terms.get),
        "model_flops": mf,
        "useful_flops_ratio": round(mf / flops, 4) if flops else 0.0,
        "collectives": coll_by_op,
    }
    if mem is not None:
        arg_b = int(getattr(mem, "argument_size_in_bytes", 0))
        tmp_b = int(getattr(mem, "temp_size_in_bytes", 0))
        out_b = int(getattr(mem, "output_size_in_bytes", 0))
        row["argument_bytes"] = arg_b
        row["temp_bytes"] = tmp_b
        row["output_bytes"] = out_b
        row["peak_bytes_per_device"] = arg_b + tmp_b
    if verbose:
        print(json.dumps(row))
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["paper_95m", "paper_1b", "paper_3b",
                                                  "phi4_mini_3_8b_swa"], default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--optimizer", default="basis_rotation")
    ap.add_argument("--rotation-source", default=None, choices=["1st", "2nd"])
    ap.add_argument("--rotation-geometry", default=None, choices=["unilateral", "bilateral"])
    ap.add_argument("--out", default=None)
    # perf-iteration knobs (EXPERIMENTS.md §Perf)
    ap.add_argument("--variant", default="", help="label recorded in the row")
    ap.add_argument("--bf16-logits", action="store_true")
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--remat-policy", default=None, choices=["full", "dots"])
    ap.add_argument("--grad-rs", action="store_true",
                    help="constrain grads to param sharding (reduce-scatter)")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence parallelism for the residual stream")
    ap.add_argument("--no-extrap", action="store_true",
                    help="compile proof + memory only (skip cost extrapolation)")
    ap.add_argument("--loss-chunk", type=int, default=None,
                    help="chunked cross-entropy (sequence chunk length)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches in the train step")
    args = ap.parse_args()

    overrides = {}
    if args.bf16_logits:
        overrides["logits_fp32"] = False
    if args.remat_policy:
        overrides["remat_policy"] = args.remat_policy
    if args.seq_shard:
        overrides["seq_sharded"] = True
    if args.loss_chunk is not None:
        overrides["loss_chunk"] = args.loss_chunk

    rotation = None
    if args.rotation_source and args.rotation_geometry:
        rotation = (args.rotation_source, args.rotation_geometry)

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all) required"
        combos = [(args.arch, args.shape)]

    rows = []
    for a, s in combos:
        try:
            ov = dict(overrides)
            if args.moe_group is not None:
                cfg0 = get_config(a)
                if cfg0.moe is not None:
                    import dataclasses as _dc

                    ov["moe"] = _dc.replace(cfg0.moe, group_size=args.moe_group)
            row = dryrun_one(a, s, args.multi_pod, args.optimizer, rotation,
                             overrides=ov or None, grad_rs=args.grad_rs,
                             variant=args.variant,
                             extrapolate=not args.no_extrap,
                             microbatches=args.microbatches)
            rows.append(row)
        except Exception as e:  # noqa: BLE001 — record failures, keep going
            rows.append({"arch": a, "shape": s, "multi_pod": args.multi_pod,
                         "status": "error", "error": f"{type(e).__name__}: {e}"})
            print(json.dumps(rows[-1]))
        if args.out:  # write incrementally: long sweeps survive interruption
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rows[-1]) + "\n")


if __name__ == "__main__":
    main()
