"""Roofline-term extraction from compiled XLA artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. The three terms (seconds, per chip):

    compute    = FLOPs_per_chip / 197e12
    memory     = HBM_bytes_per_chip / 819e9
    collective = collective_bytes_per_chip / 50e9

`cost_analysis()` on an SPMD-partitioned executable reports per-device
FLOPs/bytes (shapes in the post-partitioning module are per-device), so no
further division by chip count is applied. Collective bytes are not in
cost_analysis: we parse the optimized HLO and sum the output-type sizes of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute (== operand size for AR/a2a/permute; the gathered /
scattered size for AG/RS — the per-device traffic proxy).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

# The collective scanner is shared with the static HLO auditor
# (`repro.analysis.hlo` owns parsing; this module owns the bandwidth math).
# Names re-exported for existing callers.
from repro.analysis.hlo import (  # noqa: F401
    COLLECTIVE_OPS,
    DTYPE_BYTES as _DTYPE_BYTES,
    SHAPE_RE as _SHAPE_RE,
    CollectiveStats,
    collective_stats,
    shape_bytes as _shape_bytes,
)

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    peak_memory_bytes: float = 0.0
    model_flops: float = 0.0
    collectives: Optional[Dict[str, int]] = None

    @property
    def step_time_s(self) -> float:
        # optimistic overlap model: terms overlap perfectly
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def row(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "peak_memory_bytes": self.peak_memory_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "collectives": self.collectives or {},
        }


def roofline_from_compiled(compiled, model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    stats = collective_stats(compiled.as_text())
    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        peak = (
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = stats.total_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=stats.total_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        bottleneck=max(terms, key=terms.get),
        peak_memory_bytes=peak,
        model_flops=model_flops,
        collectives=dict(stats.bytes_by_op),
    )


def dense_model_flops(n_params: int, tokens: int, mode: str = "train") -> float:
    """6*N*D for training; 2*N*D for a forward/decode pass."""
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_params * tokens
