"""Roofline-term extraction from compiled XLA artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. The three terms (seconds, per chip):

    compute    = FLOPs_per_chip / 197e12
    memory     = HBM_bytes_per_chip / 819e9
    collective = collective_bytes_per_chip / 50e9

`cost_analysis()` on an SPMD-partitioned executable reports per-device
FLOPs/bytes (shapes in the post-partitioning module are per-device), so no
further division by chip count is applied. Collective bytes are not in
cost_analysis: we parse the optimized HLO and sum the output-type sizes of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute (== operand size for AR/a2a/permute; the gathered /
scattered size for AG/RS — the per-device traffic proxy).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  f32[128,1024]{1,0}   or  bf16[2,8]   or tuple elements
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


# one HLO instruction: "%name = <output type(s)> <op>(...)" — we bill each
# collective by its OUTPUT type(s), which works uniformly for single and
# tuple-combined collectives (optimized HLO prints operands as bare
# instruction references without types). For all-reduce / all-to-all /
# collective-permute output size == operand size; for all-gather it is the
# gathered (larger) size and for reduce-scatter the scattered (smaller) one —
# both are natural per-device traffic proxies.
_INSTR_RE = re.compile(r"=\s*(\([^)]*\)|\S+)\s+([\w-]+?)(-start|-done)?\(")


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output-type bytes of every collective op in (optimized) HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        out_types, base, suffix = m.group(1), m.group(2), m.group(3)
        if base not in COLLECTIVE_OPS:
            continue
        if suffix == "-done":
            continue  # counted at -start
        nbytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(out_types))
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + nbytes
        stats.count_by_op[base] = stats.count_by_op.get(base, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    peak_memory_bytes: float = 0.0
    model_flops: float = 0.0
    collectives: Optional[Dict[str, int]] = None

    @property
    def step_time_s(self) -> float:
        # optimistic overlap model: terms overlap perfectly
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def row(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "peak_memory_bytes": self.peak_memory_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "collectives": self.collectives or {},
        }


def roofline_from_compiled(compiled, model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    stats = collective_stats(compiled.as_text())
    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        peak = (
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = stats.total_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=stats.total_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        bottleneck=max(terms, key=terms.get),
        peak_memory_bytes=peak,
        model_flops=model_flops,
        collectives=dict(stats.bytes_by_op),
    )


def dense_model_flops(n_params: int, tokens: int, mode: str = "train") -> float:
    """6*N*D for training; 2*N*D for a forward/decode pass."""
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_params * tokens
