"""Shared pre-`import jax` device-count bootstrap.

Every CPU entry point used to clobber ``XLA_FLAGS`` with its own
``--xla_force_host_platform_device_count=N`` assignment (train, the
dry-runs, the analysis matrix, the benchmark subprocess templates) — losing
any flags the user had exported and forcing host devices even on machines
whose accelerators already provide them. `ensure_host_devices` is the one
place that decision lives now:

* it APPENDS to ``XLA_FLAGS`` instead of replacing it, so user content
  (``--xla_dump_to=...`` etc.) survives;
* it defers to a pre-existing ``xla_force_host_platform_device_count``
  setting — whoever set it first (user or an outer launcher) wins;
* it no-ops when ``JAX_PLATFORMS`` / ``JAX_PLATFORM_NAME`` names a real
  accelerator backend (tpu/gpu/cuda/rocm): those platforms bring their own
  devices and the flag only affects the CPU platform anyway;
* in a multi-controller launch (`repro.launch.distributed`), callers pass
  the PER-PROCESS device count — each process only needs to force its local
  share of the global topology.

It contains no jax imports and MUST be called before anything imports jax:
the flag is read once, at backend initialisation.
"""
from __future__ import annotations

import os
from typing import MutableMapping, Optional

FORCE_FLAG = "--xla_force_host_platform_device_count"

# platforms that provide their own devices; forcing host devices would at
# best be ignored and at worst mask a mis-set topology
ACCELERATOR_PLATFORMS = {"tpu", "gpu", "cuda", "rocm"}


def _accelerator_selected(env: MutableMapping[str, str]) -> bool:
    platforms = env.get("JAX_PLATFORMS") or env.get("JAX_PLATFORM_NAME") or ""
    names = {p.strip().lower() for p in platforms.split(",") if p.strip()}
    return bool(names & ACCELERATOR_PLATFORMS)


def ensure_host_devices(
    count: int, env: Optional[MutableMapping[str, str]] = None
) -> bool:
    """Guarantee ``count`` visible devices on CPU-only runs.

    Appends ``--xla_force_host_platform_device_count=count`` to ``XLA_FLAGS``
    in ``env`` (default ``os.environ``) unless the flag is already present
    (first setter wins) or an accelerator platform is selected. Returns True
    iff the flag was appended. Call BEFORE the first jax import.
    """
    if env is None:
        env = os.environ
    if count <= 0:
        raise ValueError(f"device count must be positive, got {count}")
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return False
    if _accelerator_selected(env):
        return False
    env["XLA_FLAGS"] = f"{flags} {FORCE_FLAG}={count}".strip()
    return True
