"""Production mesh construction + JAX version-compat shims.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device;
only `dryrun.py` forces 512 host devices.

``make_mesh_compat`` / ``use_mesh`` paper over the moving mesh API surface:
``jax.sharding.AxisType`` and ``jax.set_mesh`` only exist on newer JAX
releases, while older ones spell the context manager ``with mesh:``. Every
mesh in the repo is built through these two helpers so a single site absorbs
the version skew.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Sequence, Tuple

import jax
from jax.sharding import Mesh


def make_mesh_compat(shape: Tuple[int, ...], axis_names: Sequence[str]) -> Mesh:
    """`jax.make_mesh` with Auto axis types where the installed JAX has them."""
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(
            tuple(shape), tuple(axis_names),
            axis_types=(AxisType.Auto,) * len(axis_names),
        )
    except ImportError:
        return jax.make_mesh(tuple(shape), tuple(axis_names))


def make_process_mesh(shape: Tuple[int, ...], axis_names: Sequence[str]) -> Mesh:
    """Row-major mesh over the raw global device list (multi-controller path).

    `jax.make_mesh` may permute devices for ICI locality; multi-process
    data loading and checkpoint shard ownership assume the device grid is
    exactly `jax.devices()` reshaped row-major, so process slabs line up
    with contiguous (pod, stage, data) slabs. Built through the raw `Mesh`
    constructor to pin that order.
    """
    import numpy as np

    devices = np.array(jax.devices())
    n = 1
    for s in shape:
        n *= s
    if devices.size != n:
        raise ValueError(
            f"{devices.size} global devices do not fill mesh shape {shape}"
        )
    return Mesh(devices.reshape(tuple(shape)), tuple(axis_names))


def use_mesh(mesh: Mesh):
    """Context manager installing `mesh` as the ambient mesh.

    Newer JAX: `jax.set_mesh`; older: the Mesh object itself is the context
    manager. (`jax.sharding.use_mesh` existed briefly in between.)
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext()  # pragma: no cover — future-proofing


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_smoke_mesh() -> Mesh:
    """Single-device mesh with the production axis names (for tests)."""
    return make_mesh_compat((1, 1), ("data", "model"))


def mesh_shape_dict(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
