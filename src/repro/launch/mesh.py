"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device;
only `dryrun.py` forces 512 host devices.
"""
from __future__ import annotations

from typing import Dict

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh() -> Mesh:
    """Single-device mesh with the production axis names (for tests)."""
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)


def mesh_shape_dict(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
