"""Single-machine multi-controller launcher (CI-sized `jax.distributed`).

Forks N REAL OS processes, each running ``python -m repro.launch.train``
under the ``REPRO_*`` env contract (`repro.launch.distributed`), with a
fresh coordinator port on 127.0.0.1. This is the same code path a cluster
scheduler exercises across machines — one process per host slab, gloo CPU
collectives, per-process checkpoint shard writes — shrunk to one box so CI
can run it.

    PYTHONPATH=src python -m repro.launch.spawn --procs 2 -- \\
        --backend spmd --smoke --stages 2 --steps 12 ...

Elastic-topology scenario in ONE invocation: ``--kill-pod-at S`` polls the
run's checkpoint manifest until step >= S, SIGKILLs the highest-index
process (the "lost pod"), tears down the survivors after ``--grace``
seconds, then relaunches ``--resume-procs M`` processes with the
``--resume-with`` train arguments (typically a SMALLER topology pointed at
the same --ckpt-dir) and waits for them to finish. Exit status is 0 iff the
FINAL phase ran to completion on every process.

Worker env notes: the launcher strips any inherited
``--xla_force_host_platform_device_count`` from ``XLA_FLAGS`` (each worker
re-derives its LOCAL share via `launch.devices.ensure_host_devices`; an
outer test harness's global count would be wrong for a slab), forces
``JAX_PLATFORMS=cpu`` unless already set, and prepends this tree's ``src``
to ``PYTHONPATH`` so workers import the same checkout.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import shlex
import signal
import socket
import subprocess
import sys
import time
from typing import List, Optional, Sequence

from repro.launch.devices import FORCE_FLAG
from repro.launch.distributed import (
    ENV_COORDINATOR,
    ENV_NUM_PROCESSES,
    ENV_PROCESS_ID,
)

_SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def worker_env(num_processes: int, process_id: int, coordinator: str) -> dict:
    env = dict(os.environ)
    env[ENV_COORDINATOR] = coordinator
    env[ENV_NUM_PROCESSES] = str(num_processes)
    env[ENV_PROCESS_ID] = str(process_id)
    env.setdefault("JAX_PLATFORMS", "cpu")
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        # an outer harness forced a GLOBAL device count; workers must force
        # their local slab instead (train.py re-derives it)
        flags = re.sub(rf"{FORCE_FLAG}=\d+\s*", "", flags).strip()
        if flags:
            env["XLA_FLAGS"] = flags
        else:
            env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = _SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    return env


def manifest_step(ckpt_dir: str) -> Optional[int]:
    """Step of the last COMMITTED checkpoint under ckpt_dir, or None."""
    try:
        with open(os.path.join(ckpt_dir, "manifest.json")) as f:
            return int(json.load(f).get("step", 0))
    except (OSError, ValueError, TypeError):
        return None  # absent or mid-commit


def _terminate(procs: Sequence[subprocess.Popen], sig=signal.SIGTERM) -> None:
    for p in procs:
        if p.poll() is None:
            try:
                p.send_signal(sig)
            except OSError:  # pragma: no cover — already reaped
                pass


def _wait_all(procs: Sequence[subprocess.Popen], deadline: float) -> bool:
    """True iff every process exited 0 before `deadline`."""
    while time.time() < deadline:
        codes = [p.poll() for p in procs]
        if all(c is not None for c in codes):
            return all(c == 0 for c in codes)
        if any(c not in (None, 0) for c in codes):
            # one worker died — the rest would hang on its collectives
            _terminate(procs)
        time.sleep(0.2)
    _terminate(procs, signal.SIGKILL)
    return False


def launch_phase(
    num_processes: int, train_args: Sequence[str], deadline: float
) -> List[subprocess.Popen]:
    coordinator = f"127.0.0.1:{free_port()}"
    procs = []
    for i in range(num_processes):
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.train", *train_args],
            env=worker_env(num_processes, i, coordinator),
        ))
    return procs


def _train_arg(train_args: Sequence[str], flag: str) -> Optional[str]:
    for i, a in enumerate(train_args):
        if a == flag and i + 1 < len(train_args):
            return train_args[i + 1]
        if a.startswith(flag + "="):
            return a.split("=", 1)[1]
    return None


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--procs", type=int, required=True,
                    help="process count for the first phase")
    ap.add_argument("--timeout", type=float, default=900.0,
                    help="overall wall-clock budget (seconds)")
    ap.add_argument("--kill-pod-at", type=int, default=0,
                    help="poll the run's --ckpt-dir manifest until this step "
                         "is committed, then SIGKILL the last process (the "
                         "'lost pod') and tear the phase down")
    ap.add_argument("--grace", type=float, default=10.0,
                    help="seconds survivors get to exit after the kill "
                         "before SIGTERM")
    ap.add_argument("--resume-procs", type=int, default=0,
                    help="second phase: relaunch this many processes after "
                         "the first phase ends")
    ap.add_argument("--resume-with", default="",
                    help="full train argument string for the resume phase "
                         "(shlex-split), e.g. a smaller topology pointed at "
                         "the same --ckpt-dir")
    ap.add_argument("train_args", nargs=argparse.REMAINDER,
                    help="arguments after -- go to repro.launch.train")
    args = ap.parse_args(argv)
    if args.train_args and args.train_args[0] == "--":
        args.train_args = args.train_args[1:]
    return args


def main(argv=None) -> int:
    args = parse_args(argv)
    deadline = time.time() + args.timeout

    print(f"[spawn] phase 1: {args.procs} processes: "
          f"train {' '.join(args.train_args)}", flush=True)
    procs = launch_phase(args.procs, args.train_args, deadline)

    if args.kill_pod_at:
        ckpt_dir = _train_arg(args.train_args, "--ckpt-dir")
        if not ckpt_dir:
            _terminate(procs, signal.SIGKILL)
            raise SystemExit("--kill-pod-at needs --ckpt-dir in the train args")
        victim = procs[-1]
        while time.time() < deadline:
            step = manifest_step(ckpt_dir)
            if step is not None and step >= args.kill_pod_at:
                break
            if all(p.poll() is not None for p in procs):
                print("[spawn] workers exited before the kill step", flush=True)
                return 1
            time.sleep(0.2)
        else:
            _terminate(procs, signal.SIGKILL)
            print("[spawn] timed out waiting for the kill step", flush=True)
            return 1
        print(f"[spawn] pod loss: SIGKILL process {args.procs - 1} "
              f"at checkpoint step >= {args.kill_pod_at}", flush=True)
        if victim.poll() is None:
            victim.send_signal(signal.SIGKILL)
        # survivors hang on the dead process's collectives; give them
        # --grace to error out on their own, then tear them down
        grace_end = min(time.time() + args.grace, deadline)
        while time.time() < grace_end:
            if all(p.poll() is not None for p in procs):
                break
            time.sleep(0.2)
        _terminate(procs)
        _wait_all(procs, min(time.time() + 10, deadline))
        phase_ok = True  # an interrupted phase is the scenario, not a failure
    else:
        phase_ok = _wait_all(procs, deadline)
        print(f"[spawn] phase 1 {'ok' if phase_ok else 'FAILED'}", flush=True)

    if not args.resume_procs:
        return 0 if phase_ok else 1

    resume_args = shlex.split(args.resume_with)
    print(f"[spawn] phase 2: {args.resume_procs} processes: "
          f"train {' '.join(resume_args)}", flush=True)
    if args.resume_procs == 1:
        # single-controller resume: no coordinator, the plain train path
        env = worker_env(1, 0, "unused")
        for k in (ENV_COORDINATOR, ENV_NUM_PROCESSES, ENV_PROCESS_ID):
            env.pop(k, None)
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.train", *resume_args], env=env,
        )
        procs2 = [proc]
    else:
        procs2 = launch_phase(args.resume_procs, resume_args, deadline)
    ok = _wait_all(procs2, deadline)
    print(f"[spawn] phase 2 {'ok' if ok else 'FAILED'}", flush=True)
    return 0 if ok and phase_ok else 1


if __name__ == "__main__":
    sys.exit(main())
