"""Launch layer: device bootstrap, process identity, topology, meshes.

Attribute access is lazy (PEP 562): `repro.launch.mesh` imports jax at
module scope, but `launch.devices` / `launch.distributed` must be importable
BEFORE the first jax import (they set/read env that jax reads once at
backend initialisation). A plain eager ``from .mesh import ...`` here would
drag jax in the moment any launch submodule is touched.
"""
_EXPORTS = {
    "Topology": "repro.launch.topology",
    "make_production_mesh": "repro.launch.mesh",
    "make_smoke_mesh": "repro.launch.mesh",
    "mesh_shape_dict": "repro.launch.mesh",
    "ensure_host_devices": "repro.launch.devices",
    "ProcessGrid": "repro.launch.distributed",
    "distributed_env": "repro.launch.distributed",
    "init_distributed": "repro.launch.distributed",
    "process_count": "repro.launch.distributed",
    "process_index": "repro.launch.distributed",
    "is_main": "repro.launch.distributed",
    "barrier": "repro.launch.distributed",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
