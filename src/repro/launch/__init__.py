from repro.launch.mesh import make_production_mesh, make_smoke_mesh, mesh_shape_dict

__all__ = ["make_production_mesh", "make_smoke_mesh", "mesh_shape_dict"]
