from repro.launch.mesh import make_production_mesh, make_smoke_mesh, mesh_shape_dict
from repro.launch.topology import Topology

__all__ = [
    "Topology",
    "make_production_mesh",
    "make_smoke_mesh",
    "mesh_shape_dict",
]
