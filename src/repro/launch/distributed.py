"""Multi-controller (`jax.distributed`) initialisation and process identity.

One process per "host": `launch/spawn.py` (or a cluster scheduler) starts N
copies of the same SPMD program, each owning a slab of the global device
grid. This module is the single place that knows how a process finds out

* whether it is part of a multi-controller run at all (the ``REPRO_*`` env
  contract spawn sets, or explicit arguments),
* its coordinates (`process_index` / `process_count` / `is_main`),
* how to rendezvous (`barrier`).

Everything else stays SPMD-agnostic: `run_loop` gates logging/metrics on
`is_main()`, `SpmdEngine` asks `process_count()` whether batches arrive as
process-local shards, and the sharded checkpointer takes `barrier` as a
plain callable. All jax imports are lazy so importing this module never
touches jax device state (the same discipline as `launch/mesh.py`), and
every helper degrades to the single-process answer when `jax.distributed`
was never initialised — single-controller behavior is bit-for-bit unchanged.

CPU multi-process runs need the gloo collectives backend
(``jax_cpu_collectives_implementation=gloo``); `init_distributed` sets it
before `jax.distributed.initialize`, which must happen before the first
backend use.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import MutableMapping, Optional

# env contract between launch/spawn.py and the worker processes
ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"


@dataclass(frozen=True)
class ProcessGrid:
    """Resolved multi-controller coordinates of THIS process."""

    num_processes: int = 1
    process_index: int = 0
    coordinator: Optional[str] = None

    def __post_init__(self):
        if self.num_processes < 1:
            raise ValueError(f"num_processes must be >= 1, got {self}")
        if not 0 <= self.process_index < self.num_processes:
            raise ValueError(f"process_index out of range: {self}")

    @property
    def distributed(self) -> bool:
        return self.num_processes > 1

    def describe(self) -> str:
        return f"process {self.process_index}/{self.num_processes}"


def distributed_env(
    env: Optional[MutableMapping[str, str]] = None,
) -> Optional[ProcessGrid]:
    """The `ProcessGrid` a launcher requested via env, or None outside one.

    All three variables must be present — a partial contract is a launcher
    bug, reported loudly instead of silently running single-process.
    """
    if env is None:
        env = os.environ
    keys = (ENV_COORDINATOR, ENV_NUM_PROCESSES, ENV_PROCESS_ID)
    present = [k for k in keys if k in env]
    if not present:
        return None
    if len(present) != len(keys):
        missing = sorted(set(keys) - set(present))
        raise RuntimeError(
            f"partial multi-controller env: {present} set but {missing} "
            f"missing (launch/spawn.py sets all three)"
        )
    return ProcessGrid(
        num_processes=int(env[ENV_NUM_PROCESSES]),
        process_index=int(env[ENV_PROCESS_ID]),
        coordinator=env[ENV_COORDINATOR],
    )


def init_distributed(grid: Optional[ProcessGrid] = None) -> ProcessGrid:
    """Initialise `jax.distributed` for `grid` (default: the env contract).

    No-op (returns the single-process grid) when no multi-controller launch
    was requested. Must run before the first jax backend use; safe to call
    exactly once per process.
    """
    if grid is None:
        grid = distributed_env()
    if grid is None or not grid.distributed:
        return grid or ProcessGrid()
    if grid.coordinator is None:
        raise ValueError(f"multi-process grid needs a coordinator: {grid}")
    import jax

    # CPU cross-process collectives go through gloo; the flag must be set
    # before the CPU client is created (older jax without the option simply
    # doesn't support multi-process CPU — let initialize surface that)
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # pragma: no cover — newer/older jax
        pass
    jax.distributed.initialize(
        coordinator_address=grid.coordinator,
        num_processes=grid.num_processes,
        process_id=grid.process_index,
    )
    return grid


def process_count() -> int:
    """Global process count (1 when jax.distributed was never initialised)."""
    try:
        import jax

        return jax.process_count()
    except Exception:  # pragma: no cover — jax absent/uninitialisable
        return 1


def process_index() -> int:
    try:
        import jax

        return jax.process_index()
    except Exception:  # pragma: no cover
        return 0


def is_main() -> bool:
    """True on the process that owns logging, metrics files and manifests."""
    return process_index() == 0


def barrier(name: str) -> None:
    """Block until every process reaches the same named barrier.

    Single-process: returns immediately. Multi-process: a coordination-
    service RPC (`DistributedRuntimeClient.wait_at_barrier`) — NOT a device
    collective. That distinction is load-bearing for async checkpointing
    (DESIGN.md §11): the background writer thread runs these barriers while
    the loop thread runs compiled step collectives, and gloo cannot have
    two collectives from the same process in flight (interleaved messages
    trip `op.preamble.length <= op.nbytes`). An RPC barrier matches by
    name on the coordinator, so two processes saving different steps hang
    at distinct names and fail by timeout instead of corrupting state.

    Falls back to `multihost_utils.sync_global_devices` (a tiny psum) only
    when no distributed client exists — that path is NOT safe off the main
    thread.
    """
    if process_count() == 1:
        return
    from jax._src import distributed

    client = getattr(distributed.global_state, "client", None)
    if client is not None:
        client.wait_at_barrier(name, timeout_in_ms=600_000)
        return
    from jax.experimental import multihost_utils

    multihost_utils.sync_global_devices(name)


def assert_process_slabs() -> None:
    """Verify `jax.devices()` orders each process's devices as one contiguous
    slab (process-major) — the layout `Topology.process_data_shards` and the
    checkpoint shard-ownership map assume. Holds for every standard backend;
    a permuted order means those maps would silently mis-assign rows."""
    import jax

    n, p = len(jax.devices()), process_count()
    if p == 1:
        return
    assert n % p == 0, f"{n} devices not divisible over {p} processes"
    per = n // p
    for i, d in enumerate(jax.devices()):
        if d.process_index != i // per:
            raise RuntimeError(
                f"jax.devices() is not process-slab ordered: device {i} "
                f"belongs to process {d.process_index}, expected {i // per}"
            )
