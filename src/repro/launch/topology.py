"""First-class description of the (pod, stage, data) device topology.

Every entry point used to hand-roll its own mesh: `SpmdEngine` built a
single-host (stage, data) mesh, `dryrun_pipeline.py` a fake 512-chip
(pod, stage, data) mesh, and the benchmarks a third variant. `Topology` is
the single owner of that decision: it names the axes, builds the mesh
(through the version-compat shims in `repro.launch.mesh`), derives the
PartitionSpecs for stage-stacked parameters and microbatched token streams,
and tells the data pipeline how many host shards the global batch splits
into. Everything downstream — the tick schedules' gradient reductions, the
engine's batch validation, the sharded checkpointer's shard count — reads
the same object instead of re-deriving axis names.

Axis layout (pod-major, matching the production dry-run):

    pods == 1 :  (stage, data)            e.g. 16 x 16  = one 256-chip pod
    pods >= 2 :  (pod, stage, data)       e.g. 2 x 16 x 16 = two pods

The pod axis is OMITTED from the mesh when ``pods == 1`` so single-pod
programs keep the exact mesh shape (and therefore compiled layout) they had
before the abstraction existed; the schedules receive the data-reduction
axes as a tuple whenever the pod axis is real, which makes gradient
all-reduces span ``("pod", "data")`` — combined data parallelism across
pods, the regime AsyncMesh calls out as the interesting one.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

STAGE_AXIS = "stage"
DATA_AXIS = "data"
POD_AXIS = "pod"


@dataclass(frozen=True)
class Topology:
    """(pod, stage, data) shape of one SPMD pipeline deployment."""

    stages: int
    data: int = 1
    pods: int = 1

    def __post_init__(self):
        if self.stages < 1 or self.data < 1 or self.pods < 1:
            raise ValueError(f"all topology axes must be >= 1, got {self}")

    # -- shape ---------------------------------------------------------------

    @property
    def shape(self) -> Tuple[int, ...]:
        if self.pods == 1:
            return (self.stages, self.data)
        return (self.pods, self.stages, self.data)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        if self.pods == 1:
            return (STAGE_AXIS, DATA_AXIS)
        return (POD_AXIS, STAGE_AXIS, DATA_AXIS)

    @property
    def num_devices(self) -> int:
        return self.pods * self.stages * self.data

    @property
    def data_shards(self) -> int:
        """Ways the global batch is split: the full (pod, data) extent."""
        return self.pods * self.data

    @property
    def data_axes(self) -> Tuple[str, ...]:
        """Mesh axes that carry data parallelism (gradient all-reduce group)."""
        if self.pods == 1:
            return (DATA_AXIS,)
        return (POD_AXIS, DATA_AXIS)

    @property
    def schedule_data_axis(self) -> Union[str, Tuple[str, ...]]:
        """``data_axis`` argument for the tick schedules: the bare axis name
        single-pod (the historical path), the ("pod", "data") tuple multi-pod."""
        if self.pods == 1:
            return DATA_AXIS
        return self.data_axes

    def describe(self) -> str:
        return "x".join(str(s) for s in self.shape)

    def replica_groups(self, axes: Tuple[str, ...]) -> Tuple[Tuple[int, ...], ...]:
        """Replica groups of a collective over ``axes``, as flattened
        positions in the device assignment (row-major over `shape`).

        A reduction over an axis subset partitions the devices by their
        coordinates on the remaining axes — this is the ground truth the
        static HLO auditor (`repro.analysis.hlo`) compares every compiled
        collective against, so only groupings constructible here count as
        "declared by the topology".
        """
        import numpy as np

        unknown = set(axes) - set(self.axis_names)
        if not axes or unknown:
            raise ValueError(
                f"axes {axes} not declared by topology {self.describe()} "
                f"with axes {self.axis_names}"
            )
        names = self.axis_names
        keep = [i for i, n in enumerate(names) if n not in axes]
        move = [i for i, n in enumerate(names) if n in axes]
        ids = np.arange(self.num_devices).reshape(self.shape)
        grouped = ids.transpose(keep + move).reshape(
            -1, int(np.prod([self.shape[i] for i in move]))
        )
        return tuple(tuple(int(x) for x in row) for row in grouped)

    # -- process grid (multi-controller) -------------------------------------

    def local_device_count(self, num_processes: int) -> int:
        """Devices each process contributes: the global grid split into
        equal process-major slabs."""
        if num_processes < 1 or self.num_devices % num_processes != 0:
            raise ValueError(
                f"{self.num_devices} devices of topology {self.describe()} "
                f"do not split over {num_processes} processes"
            )
        return self.num_devices // num_processes

    def _process_coords(self, num_processes: int, process_index: int):
        """(pod, stage, data)-style coordinate rows of one process's slab of
        the row-major global device grid."""
        import numpy as np

        per = self.local_device_count(num_processes)
        if not 0 <= process_index < num_processes:
            raise ValueError(
                f"process_index {process_index} out of range for "
                f"{num_processes} processes"
            )
        flat = np.arange(process_index * per, (process_index + 1) * per)
        return np.stack(np.unravel_index(flat, self.shape), axis=1)

    def process_data_shards(
        self, num_processes: int, process_index: int
    ) -> Tuple[int, int]:
        """Half-open range ``[lo, hi)`` of global data-shard indices (pod-
        major, `data_shards` total) whose batch rows this process must
        supply to `jax.make_array_from_process_local_data`.

        The range is the union of the (pod, data) coordinates of the
        process's device slab — contiguous whenever process boundaries
        don't cut a stage's data extent unevenly (guaranteed when the
        per-process device count and the data extent divide one another,
        the only layouts the launcher produces). Processes that only hold
        stage replicas of the same rows get overlapping ranges — each
        supplies its addressable copy, exactly what the assembly API wants.
        """
        coords = self._process_coords(num_processes, process_index)
        if self.pods == 1:
            rows = coords[:, 1]  # (stage, data) -> data coordinate
        else:
            rows = coords[:, 0] * self.data + coords[:, 2]
        uniq = sorted(set(int(r) for r in rows))
        lo, hi = uniq[0], uniq[-1] + 1
        if uniq != list(range(lo, hi)):
            raise ValueError(
                f"process {process_index}/{num_processes} of topology "
                f"{self.describe()} owns non-contiguous data shards {uniq}; "
                f"choose a process count whose slab size divides (or is a "
                f"multiple of) the data extent"
            )
        return lo, hi

    def shard_owners(self, num_processes: int) -> Tuple[int, ...]:
        """Which process writes checkpoint shard (= pipeline stage) ``s``.

        Candidates are the processes whose device slab touches stage ``s``
        (they address that slice of every stage-sharded leaf); ownership
        round-robins over them so pod-replicated layouts spread the write
        load instead of piling every shard on process 0. Exactly one owner
        per shard — the disjoint-write invariant the multi-process
        checkpointer relies on.
        """
        owners = []
        self.local_device_count(num_processes)  # validate divisibility
        stage_pos = 0 if self.pods == 1 else 1
        by_stage: dict = {}
        for p in range(num_processes):
            for c in self._process_coords(num_processes, p):
                by_stage.setdefault(int(c[stage_pos]), []).append(p)
        for s in range(self.stages):
            cands = sorted(set(by_stage[s]))
            owners.append(cands[s % len(cands)])
        return tuple(owners)

    # -- mesh + specs --------------------------------------------------------

    def make_mesh(self) -> Mesh:
        from repro.launch.mesh import make_mesh_compat, make_process_mesh

        import jax

        if jax.process_count() > 1:
            # multi-controller: the device grid must be row-major so process
            # slabs align with the (pod, stage, data) slabs the data loader
            # and checkpoint shard-ownership maps assume (jax.make_mesh may
            # permute devices for ICI locality)
            return make_process_mesh(self.shape, self.axis_names)
        return make_mesh_compat(self.shape, self.axis_names)

    def stage_spec(self, ndim: int) -> P:
        """Stage-stacked leaf of rank ``ndim``: leading axis over `stage`."""
        return P(STAGE_AXIS, *([None] * (ndim - 1)))

    def batch_spec(self) -> P:
        """(M, mb, S) microbatched tokens: mb sharded over every data axis."""
        return P(None, self.data_axes, None)

    def replicated_spec(self) -> P:
        return P()

    # -- constructors --------------------------------------------------------

    @classmethod
    def single_host(cls, stages: int, data: int = 1) -> "Topology":
        """Test/smoke shape: K forced host devices, optional data axis."""
        return cls(stages=stages, data=data)

    @classmethod
    def single_pod(cls, stages: int = 16, data: int = 16) -> "Topology":
        """The production 16x16 pod (256 chips)."""
        return cls(stages=stages, data=data)

    @classmethod
    def multi_pod(cls, pods: int = 2, stages: int = 16, data: int = 16) -> "Topology":
        """Pod-replicated production shape, e.g. 2x16x16 = 512 chips."""
        return cls(stages=stages, data=data, pods=pods)

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "Topology":
        """Recover the topology from a mesh built with the canonical axes."""
        dims = dict(zip(mesh.axis_names, mesh.devices.shape))
        unknown = set(dims) - {POD_AXIS, STAGE_AXIS, DATA_AXIS}
        if unknown or STAGE_AXIS not in dims:
            raise ValueError(
                f"mesh axes {mesh.axis_names} are not a pipeline topology "
                f"(expected a subset of (pod, stage, data) containing stage)"
            )
        return cls(stages=dims[STAGE_AXIS], data=dims.get(DATA_AXIS, 1),
                   pods=dims.get(POD_AXIS, 1))

    @classmethod
    def from_device_count(
        cls, stages: int, pods: int = 1, data: int = 0,
        device_count: Optional[int] = None,
    ) -> "Topology":
        """Fill in the data axis from the visible device count.

        ``data == 0`` means "use every device": data = n // (pods * stages).
        On CPU, force host devices first (``--xla_force_host_platform_
        device_count``).
        """
        if device_count is None:
            import jax

            device_count = len(jax.devices())
        if data <= 0:
            if device_count % (pods * stages) != 0:
                raise ValueError(
                    f"{device_count} devices not divisible by pods*stages = "
                    f"{pods}*{stages}"
                )
            data = device_count // (pods * stages)
        return cls(stages=stages, data=data, pods=pods)

    @classmethod
    def from_process_grid(
        cls, stages: int, num_processes: int, local_device_count: int,
        pods: int = 1, data: int = 0,
    ) -> "Topology":
        """Multi-controller constructor: the global grid is the union of
        ``num_processes`` slabs of ``local_device_count`` devices each;
        ``data == 0`` fills the data axis from that total (mirroring
        `from_device_count` for the single-controller path)."""
        return cls.from_device_count(
            stages, pods=pods, data=data,
            device_count=num_processes * local_device_count,
        )
