"""End-to-end training driver (single-host simulation of K-stage async
pipeline parallelism — the paper's experimental setup).

    PYTHONPATH=src python -m repro.launch.train \\
        --arch paper_95m --stages 8 --optimizer basis_rotation \\
        --steps 300 --batch 8 --seq 256 --lr 1e-3

Checkpoints land under --ckpt-dir every --ckpt-every steps and training
resumes from the latest one if present.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import OptimizerConfig, get_config
from repro.data import batches
from repro.models import init_model, param_count
from repro.optim.base import make_schedule
from repro.optim.factory import build_optimizer
from repro.pipeline.partition import delay_tree
from repro.pipeline.simulate import make_sim_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_95m")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--stages", type=int, default=8)
    ap.add_argument("--optimizer", default="basis_rotation")
    ap.add_argument("--rotation-source", default="2nd", choices=["1st", "2nd"])
    ap.add_argument("--rotation-geometry", default="bilateral",
                    choices=["unilateral", "bilateral"])
    ap.add_argument("--rotation-freq", type=int, default=10)
    ap.add_argument("--stage-aware", action="store_true")
    ap.add_argument("--weight-prediction", action="store_true")
    ap.add_argument("--no-stash", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--out", default=None, help="write the loss curve as JSON")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    # the simulator needs per-layer leaves for per-stage delays
    cfg = cfg.replace(scan_layers=False, dtype="float32", param_dtype="float32")
    if cfg.num_layers % args.stages != 0:
        raise SystemExit(f"--stages {args.stages} must divide {cfg.num_layers} layers")

    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    print(f"arch={cfg.name} params={param_count(params):,} stages={args.stages} "
          f"optimizer={args.optimizer}")

    ocfg = OptimizerConfig(
        name=args.optimizer, learning_rate=args.lr, total_steps=args.steps,
        rotation_source=args.rotation_source,
        rotation_geometry=args.rotation_geometry,
        rotation_freq=args.rotation_freq, stage_aware=args.stage_aware,
    )
    opt = build_optimizer(ocfg, params, cfg, num_stages=args.stages)
    opt_state = opt.init(params)
    sched = make_schedule(ocfg.schedule, ocfg.learning_rate, ocfg.total_steps,
                          ocfg.warmup_frac)
    dtree = delay_tree(params, cfg, args.stages)

    start_step = 0
    if args.ckpt_dir and os.path.exists(os.path.join(args.ckpt_dir, "manifest.json")):
        (params, opt_state), start_step, _ = load_checkpoint(args.ckpt_dir)
        print(f"resumed from {args.ckpt_dir} at step {start_step}")

    step_fn = make_sim_train_step(
        cfg, opt, grad_clip=1.0,
        weight_prediction=args.weight_prediction, delays_tree=dtree,
        schedule=sched, no_stash=args.no_stash,
    )
    data = batches(cfg, args.batch, args.seq, seed=args.seed)
    from repro.pipeline.simulate import stale_forward_params

    max_age = max(int(d) for d in jax.tree_util.tree_leaves(dtree)) if args.no_stash else 0
    history = []

    losses = []
    t0 = time.time()
    for t in range(start_step, args.steps):
        batch = next(data)
        fwd_hist = (
            stale_forward_params(history, params, dtree) if args.no_stash else 0
        )
        params, opt_state, loss, metrics = step_fn(
            params, opt_state, fwd_hist, batch, jnp.int32(t)
        )
        if args.no_stash and max_age:
            history.append(params)
            history = history[-(max_age + 1):]
        losses.append(float(loss))
        if t % args.log_every == 0:
            dt = time.time() - t0
            print(f"step {t:5d}  loss {losses[-1]:.4f}  ce {float(metrics['ce']):.4f}"
                  f"  ({dt:.1f}s)")
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, (params, opt_state), step=t + 1)
        if args.out and (t + 1) % max(args.log_every, 1) == 0:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:  # incremental: survives interruption
                json.dump({"arch": cfg.name, "optimizer": args.optimizer,
                           "stages": args.stages, "steps_done": t + 1,
                           "losses": losses}, f)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, (params, opt_state), step=args.steps)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"arch": cfg.name, "optimizer": args.optimizer,
                       "stages": args.stages, "losses": losses}, f)
    print(f"final loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
