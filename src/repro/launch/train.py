"""End-to-end training driver over the unified pipeline engine.

Two backends behind the same loop (`repro.engine`):

  * ``--backend sim``  (default): single-program simulation of K-stage async
    pipeline parallelism — the paper's experimental setup. Staleness is
    imposed exactly by the per-leaf gradient FIFO.
  * ``--backend spmd``: the shard_map pipeline runtime — layers sharded over
    a `stage` mesh axis, ppermute moving activations under a scanned tick
    schedule (``--schedule fill_drain`` or ``1f1b``; 1F1B bounds the live
    activation stash at O(stages) instead of O(microbatches)), and the
    per-stage delay FIFO applying PipeDream weight-stashing staleness to the
    stage-stacked parameters. ``--pods`` / ``--data-par`` place the run on a
    `(pod, stage, data)` `Topology` (gradients all-reduce over
    ``("pod", "data")``, checkpoints save one arrays file per stage shard,
    and multi-pod runs load data host-sharded via
    ``data.synthetic.sharded_batches``). On a CPU-only host the driver
    forces ``pods*stages*data`` host devices automatically; on accelerator
    machines with a different device count, re-run with
    ``JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=N``.

    The spmd backend also runs TRUE multi-controller: start N copies of this
    driver (``repro.launch.spawn`` does it on one machine) with either the
    ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``
    env contract or ``--coordinator/--num-processes/--process-id``. Each
    process brings ``num_devices/N`` local devices, loads only its data
    shards (`data.synthetic.process_local_batches`), writes only its own
    checkpoint shard files, and process 0 alone logs, writes metrics JSON
    and commits checkpoint manifests. Resuming on a different process count
    or smaller `Topology` (elastic resume after losing a pod) goes through
    the same format-agnostic checkpoint loader.

    PYTHONPATH=src python -m repro.launch.train \\
        --arch paper_95m --stages 8 --optimizer basis_rotation \\
        --steps 300 --batch 8 --seq 256 --lr 1e-3 [--backend spmd]

Checkpoints land under --ckpt-dir every --ckpt-every steps and training
resumes from the latest one if present.
"""
from __future__ import annotations

import argparse
import math


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_95m")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--backend", default="sim", choices=["sim", "spmd"])
    ap.add_argument("--stages", type=int, default=8)
    ap.add_argument("--pods", type=int, default=1,
                    help="spmd backend: pod-replicated (pod, stage, data) "
                         "topology; gradients all-reduce over (pod, data)")
    ap.add_argument("--data-par", type=int, default=0,
                    help="spmd backend: data-parallel axis size per pod "
                         "(default 0 = use every visible device)")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="spmd backend: pipeline microbatches (default: stages)")
    # literal list (not engine.schedules.SCHEDULES): importing repro.engine
    # pulls in jax, which must not happen before main() sets XLA_FLAGS
    ap.add_argument("--schedule", default="fill_drain",
                    choices=["fill_drain", "1f1b"],
                    help="spmd backend: tick schedule (1f1b bounds the "
                         "activation stash at O(stages) instead of O(M))")
    ap.add_argument("--optimizer", default="basis_rotation")
    ap.add_argument("--rotation-source", default="2nd", choices=["1st", "2nd"])
    ap.add_argument("--rotation-geometry", default="bilateral",
                    choices=["unilateral", "bilateral"])
    ap.add_argument("--rotation-freq", type=int, default=10)
    ap.add_argument("--stage-aware", action="store_true")
    ap.add_argument("--use-kernels", action="store_true",
                    help="route the fused flash-attention stage apply (fwd + "
                         "custom-vjp bwd) and the optimizer matmuls / fused "
                         "Adam scale through the Pallas kernels (interpret "
                         "mode off-TPU)")
    ap.add_argument("--precision", default="f32", choices=["f32", "bf16"],
                    help="spmd backend: precision policy — bf16 runs "
                         "activations/matmuls in bf16 with f32 parameter "
                         "masters, optimizer state and loss accumulations")
    ap.add_argument("--weight-prediction", action="store_true")
    ap.add_argument("--no-stash", action="store_true")
    ap.add_argument("--sync", action="store_true",
                    help="synchronous gradients: no delay FIFO on either "
                         "backend (the cross-backend agreement reference)")
    ap.add_argument("--data-async", action="store_true",
                    help="asynchronous data axis: take the cross-replica "
                         "gradient all-reduce off the step critical path and "
                         "apply the --data-delay-step-old deferred reduction "
                         "instead (sim backend models it as +D uniform "
                         "gradient staleness)")
    ap.add_argument("--data-delay", type=int, default=None,
                    help="data-axis staleness D under --data-async "
                         "(default 1; 0 = bit-identical to the synchronous "
                         "data axis)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--out", default=None, help="write the loss curve as JSON")
    ap.add_argument("--coordinator", default=None,
                    help="spmd backend: jax.distributed coordinator "
                         "host:port (default: the REPRO_COORDINATOR env "
                         "contract launch/spawn.py sets)")
    ap.add_argument("--num-processes", type=int, default=0,
                    help="spmd backend: multi-controller process count "
                         "(default 0 = read the REPRO_* env contract; 1 = "
                         "force single-process)")
    ap.add_argument("--process-id", type=int, default=0,
                    help="spmd backend: this process's index in the grid")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.data_delay is not None and not args.data_async:
        raise SystemExit("--data-delay only applies under --data-async")
    if args.data_async and args.sync:
        raise SystemExit(
            "--sync forces fully synchronous gradients; it cannot be "
            "combined with --data-async"
        )
    data_delay = (
        (1 if args.data_delay is None else args.data_delay)
        if args.data_async else 0
    )
    if data_delay < 0:
        raise SystemExit("--data-delay must be >= 0")
    if args.backend == "sim" and args.schedule != "fill_drain":
        raise SystemExit(
            "--schedule picks the SPMD tick schedule; the sim backend imposes "
            "delays directly and has no schedule (use --backend spmd)"
        )
    if args.backend != "spmd" and (args.pods != 1 or args.data_par > 1):
        raise SystemExit(
            "--pods / --data-par describe the spmd device topology; the sim "
            "backend is a single-program simulation (use --backend spmd)"
        )
    # devices/distributed import no jax — safe before XLA_FLAGS is final
    from repro.launch.devices import ensure_host_devices
    from repro.launch.distributed import (
        ProcessGrid,
        distributed_env,
        init_distributed,
        is_main,
    )

    grid = ProcessGrid()
    if args.backend == "spmd":
        if args.weight_prediction or args.no_stash:
            raise SystemExit(
                "--weight-prediction / --no-stash are sim-backend modes; "
                "the spmd backend imposes weight-stashing staleness physically"
            )
        if args.num_processes:
            grid = ProcessGrid(num_processes=args.num_processes,
                               process_index=args.process_id,
                               coordinator=args.coordinator)
        else:
            grid = distributed_env() or ProcessGrid()
        # the spmd backend needs pods*stages*data devices globally; on CPU,
        # force (this process's share of) them BEFORE jax initialises its
        # backend — in a multi-controller run every process contributes an
        # equal slab of the global grid
        n_dev = args.pods * args.stages * max(args.data_par, 1)
        if n_dev % grid.num_processes:
            raise SystemExit(
                f"{grid.num_processes} processes do not split the "
                f"{n_dev}-device (pods={args.pods}, stages={args.stages}, "
                f"data={args.data_par}) topology evenly"
            )
        ensure_host_devices(n_dev // grid.num_processes)
    elif args.num_processes > 1 or args.coordinator:
        raise SystemExit(
            "--coordinator / --num-processes are spmd-backend options; the "
            "sim backend is a single-program simulation (use --backend spmd)"
        )

    import jax

    if grid.distributed:
        # rendezvous before any backend use: jax.devices() below must
        # already see the merged global device grid
        init_distributed(grid)

    main_proc = is_main()

    from repro.configs import OptimizerConfig, get_config
    from repro.data import batches, host_assembled_batches, process_local_batches
    from repro.engine import (
        LoopConfig,
        SimEngine,
        SpmdEngine,
        resume_if_present,
        run_loop,
    )
    from repro.launch.topology import Topology
    from repro.models import init_model, param_count
    from repro.optim.base import make_schedule
    from repro.optim.factory import build_optimizer
    from repro.pipeline.partition import delay_tree

    if args.precision != "f32" and args.backend != "spmd":
        raise SystemExit(
            "--precision bf16 is an spmd-backend policy; the sim backend "
            "reproduces the paper's f32 runs bit-for-bit"
        )

    from repro.configs.base import PRECISION_POLICIES

    cfg = get_config(args.arch, smoke=args.smoke)
    # both backends need per-layer leaves (per-stage delays / stage stacking);
    # the precision policy owns every dtype knob (f32 = the old forced-f32)
    cfg = PRECISION_POLICIES[args.precision].apply(
        cfg.replace(scan_layers=False)
    )
    if cfg.num_layers % args.stages != 0:
        if args.smoke:
            # pad the reduced config up to the nearest depth that both the
            # pattern and the stage count divide — smoke runs exercise the
            # machinery, not the exact layer count
            layers = math.lcm(len(cfg.pattern), args.stages)
            while layers < cfg.num_layers:
                layers += math.lcm(len(cfg.pattern), args.stages)
            if main_proc:
                print(f"smoke: padding {cfg.num_layers} layers -> {layers} "
                      f"to divide {args.stages} stages")
            cfg = cfg.replace(num_layers=layers)
        else:
            raise SystemExit(
                f"--stages {args.stages} must divide {cfg.num_layers} layers"
            )

    topology = None
    if args.backend == "spmd":
        # the flag above only helps the CPU backend; verify the topology that
        # actually came up and fail with the remedy rather than a mesh error
        n = len(jax.devices())
        try:
            topology = Topology.from_device_count(
                args.stages, pods=args.pods, data=args.data_par
            )
        except ValueError:
            topology = None
        if topology is None or topology.num_devices != n:
            # the forced-host-device flag only affects the CPU platform (and
            # only if it wasn't already set with a different count)
            want = args.pods * args.stages * max(args.data_par, 1)
            raise SystemExit(
                f"spmd backend: {n} global devices ({grid.describe()}) do "
                f"not form a (pods={args.pods}, stages={args.stages}, "
                f"data={args.data_par}) topology; re-run with "
                f"JAX_PLATFORMS=cpu XLA_FLAGS="
                f"--xla_force_host_platform_device_count="
                f"{want // grid.num_processes} on each process"
            )
        M = args.microbatches or args.stages
        shards = topology.data_shards
        if args.batch % M or (args.batch // M) % shards:
            raise SystemExit(
                f"--batch {args.batch} must split into {M} microbatches of a "
                f"size divisible by the {shards} data shard(s) of topology "
                f"{topology.describe()}"
            )

    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    topo_str = topology.describe() if topology is not None else None
    if main_proc:
        print(f"arch={cfg.name} params={param_count(params):,} "
              f"stages={args.stages} optimizer={args.optimizer} "
              f"backend={args.backend}"
              + (f" topology={topo_str}" if topo_str else "")
              + (f" {grid.describe()}" if grid.distributed else ""))

    ocfg = OptimizerConfig(
        name=args.optimizer, learning_rate=args.lr, total_steps=args.steps,
        rotation_source=args.rotation_source,
        rotation_geometry=args.rotation_geometry,
        rotation_freq=args.rotation_freq, stage_aware=args.stage_aware,
    )

    if args.backend == "spmd":
        engine = SpmdEngine(
            cfg, ocfg, num_stages=args.stages,
            num_microbatches=args.microbatches, async_grads=not args.sync,
            schedule=args.schedule, use_kernels=args.use_kernels,
            topology=topology, precision=args.precision,
            data_async=args.data_async, data_delay=data_delay,
        )
    else:
        # --sync drops the simulated delay FIFO (but keeps stage-aware
        # frequency allocation for K stages) — the same synchronous reference
        # the spmd backend produces with async_grads=False. --data-async adds
        # D uniform extra staleness to every leaf's FIFO: the sim has one
        # data replica, whose "reduction" is the identity, so delaying the
        # gradient by D IS the deferred-reduction semantics.
        opt = build_optimizer(ocfg, params, cfg, num_stages=args.stages,
                              apply_delay=not args.sync,
                              use_kernels=args.use_kernels,
                              data_delay=data_delay)
        sched = make_schedule(ocfg.schedule, ocfg.learning_rate, ocfg.total_steps,
                              ocfg.warmup_frac)
        dtree = delay_tree(params, cfg, args.stages)
        engine = SimEngine(
            cfg, opt, grad_clip=1.0,
            weight_prediction=args.weight_prediction, delays_tree=dtree,
            schedule=sched, no_stash=args.no_stash,
        )

    state = engine.init_state(params=params)
    if grid.distributed:
        # true multi-controller loading: each process yields only the
        # microbatch row shards its device slab addresses; the engine
        # assembles them into the global batch via
        # jax.make_array_from_process_local_data. Stacking the per-process
        # slices reproduces batches() bit-for-bit, so process count never
        # changes the data stream (and elastic resumes continue it exactly).
        lo, hi = topology.process_data_shards(
            grid.num_processes, grid.process_index
        )
        data = process_local_batches(
            cfg, args.batch, args.seq,
            num_microbatches=args.microbatches or args.stages,
            data_shards=topology.data_shards, shard_lo=lo, shard_hi=hi,
            seed=args.seed,
        )
    elif topology is not None and topology.pods > 1:
        # host-sharded loading, one emulated host per pod: each pod walks its
        # slice of the same seeded global stream (sharded_batches partitions
        # batches() bit-for-bit, so the topology never changes the data)
        data = host_assembled_batches(
            cfg, args.batch, args.seq, num_hosts=topology.pods, seed=args.seed
        )
    else:
        data = batches(cfg, args.batch, args.seq, seed=args.seed)
    # resume_if_present fast-forwards `data` past the consumed batches, so a
    # resumed run continues the exact uninterrupted stream (the assembled
    # sharded iterator advances every host shard in lock-step)
    state, start_step = resume_if_present(engine, state, args.ckpt_dir, data)
    if start_step and main_proc:
        print(f"resumed from {args.ckpt_dir} at step {start_step}")

    loop_cfg = LoopConfig(
        steps=args.steps, log_every=args.log_every,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        out_path=args.out,
        out_meta={"arch": cfg.name, "optimizer": args.optimizer,
                  "stages": args.stages, "backend": args.backend,
                  "schedule": args.schedule if args.backend == "spmd" else None,
                  "topology": topo_str, "precision": args.precision,
                  "use_kernels": args.use_kernels,
                  "data_async": args.data_async, "data_delay": data_delay},
    )
    _, losses = run_loop(engine, data, loop_cfg, state=state, start_step=start_step)
    if losses and main_proc:
        print(f"final loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
