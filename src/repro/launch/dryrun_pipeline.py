from repro.launch.devices import ensure_host_devices

ensure_host_devices(512)

"""Dry-run of the shard_map PIPELINE runtime (DESIGN.md §4): the paper's own
architecture, layers split over a 16-way `stage` mesh axis with ppermute
moving activations, autodiff generating the backward pipeline, and the
per-stage delayed basis-rotation optimizer applied to the stage-sharded
parameters. Proves the pipeline-parallel distribution lowers and compiles on
the production meshes:

    single-pod : (stage=16, data=16)          = 256 chips
    multi-pod  : (pod=2, stage=16, data=16)   = 512 chips

Usage: python -m repro.launch.dryrun_pipeline [--multi-pod] [--stages 16]
                                              [--schedule fill_drain|1f1b]
                                              [--stage-aware] [--use-kernels]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from repro.configs import OptimizerConfig, get_config  # noqa: E402
from repro.launch.mesh import use_mesh  # noqa: E402
from repro.launch.roofline import roofline_from_compiled  # noqa: E402
from repro.launch.topology import Topology  # noqa: E402
from repro.models.model import init_model  # noqa: E402
from repro.optim.base import apply_updates  # noqa: E402
from repro.optim.factory import build_optimizer  # noqa: E402
from repro.pipeline.spmd import (  # noqa: E402
    SCHEDULES,
    make_pipeline_grad,
    stack_stage_params,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--stages", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=32)
    ap.add_argument("--schedule", default="fill_drain", choices=SCHEDULES)
    ap.add_argument("--stage-aware", action="store_true",
                    help="per-stage basis-refresh periods over the stacked "
                         "leaves (paper Appendix I on the real runtime)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernel path for the optimizer matmuls")
    ap.add_argument("--arch", default="paper_95m")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    K, M = args.stages, args.microbatches
    cfg = get_config(args.arch).replace(scan_layers=False, dtype="bfloat16")
    assert cfg.num_layers % K == 0

    # the production shapes come from the shared Topology abstraction — the
    # same object SpmdEngine trains on (this dry-run only compiles)
    topo = (
        Topology.multi_pod(pods=2, stages=K, data=16) if args.multi_pod
        else Topology.single_pod(stages=K, data=16)
    )
    mesh = topo.make_mesh()
    mb = 32 * topo.pods  # per-microbatch global batch scales with the pods

    # stage-stacked parameter shapes (leading dim = stage, sharded on `stage`)
    params_shapes = jax.eval_shape(
        lambda k: init_model(k, cfg), jax.random.PRNGKey(0)
    )
    stacked_s, shared_s = jax.eval_shape(
        lambda p: stack_stage_params(p, cfg, K), params_shapes
    )
    stage_sh = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(
            a.shape, a.dtype,
            sharding=NamedSharding(mesh, topo.stage_spec(len(a.shape)))
        ),
        stacked_s,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    shared_sh = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(
            a.shape, a.dtype,
            sharding=NamedSharding(mesh, topo.replicated_spec())),
        shared_s,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )

    S = 512
    tok_sharding = NamedSharding(mesh, topo.batch_spec())
    batch = {
        "tokens": jax.ShapeDtypeStruct((M, mb, S), jnp.int32, sharding=tok_sharding),
        "labels": jax.ShapeDtypeStruct((M, mb, S), jnp.int32, sharding=tok_sharding),
    }

    grad_fn = make_pipeline_grad(
        cfg, mesh, K, M, schedule=args.schedule,
        data_axis=topo.schedule_data_axis,
    )

    # async step: pipeline grads + per-stage delayed basis-rotation update
    # (same composition as SpmdEngine: stacked StageContext through the
    # factory, exact per-stage tau via the diagonal FIFO)
    from repro.pipeline.delay import stage_delayed_optimizer
    from repro.pipeline.partition import stage_context_for_stacked

    ocfg = OptimizerConfig(
        name="basis_rotation", learning_rate=1e-3, total_steps=10_000,
        rotation_freq=10, stage_aware=args.stage_aware,
    )
    ctx = stage_context_for_stacked(stacked_s, shared_s, K)
    base = build_optimizer(ocfg, (stacked_s, shared_s), cfg, num_stages=K,
                           apply_delay=False, use_kernels=args.use_kernels,
                           stage_context=ctx)
    opt = stage_delayed_optimizer(base, ctx.delay_specs(), K)

    def train_step(stage_params, shared, opt_state, batch, step):
        loss, (gs, gsh) = grad_fn(stage_params, shared, batch)
        updates, opt_state = opt.update(
            (gs, gsh), opt_state, (stage_params, shared), step,
        )
        stage_params = apply_updates(stage_params, updates[0])
        shared = apply_updates(shared, updates[1])
        return stage_params, shared, opt_state, loss

    opt_state_s = jax.eval_shape(opt.init, (stacked_s, shared_s))

    def anon_sharding(a):
        return jax.ShapeDtypeStruct(a.shape, a.dtype)

    opt_in = jax.tree.map(anon_sharding, opt_state_s,
                          is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    t0 = time.time()
    with use_mesh(mesh):
        lowered = jax.jit(train_step).lower(
            stage_sh, shared_sh, opt_in, batch, jax.ShapeDtypeStruct((), jnp.int32)
        )
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    rf = roofline_from_compiled(compiled)
    row = {
        "kind": "pipeline_dryrun",
        "arch": args.arch,
        "mesh": topo.describe(),
        "stages": K,
        "microbatches": M,
        "schedule": args.schedule,
        "stage_aware": args.stage_aware,
        "use_kernels": args.use_kernels,
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "collectives": rf.collectives,
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
    }
    print(json.dumps(row))
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(row) + "\n")


if __name__ == "__main__":
    main()
