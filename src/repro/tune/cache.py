"""Persistent block-plan cache for the kernel autotuner.

One JSON file maps ``kernel|shape|dtype|platform`` keys to winning tile
plans (``{"block_q": 128, "block_k": 64, ...}`` plus provenance). The file
is shared state between tuning runs (`python -m repro.tune`) and trace-time
consumers (`kernels/flash.py::_plan`, `kernels/ops.py`), so every access is
defensive:

* a missing, corrupt, or truncated file is an EMPTY cache, never an error —
  tuning is a performance hint, not a correctness dependency;
* entries under a different schema version (or with non-dict values) are
  ignored on read and dropped on the next write — stale keys from an old
  layout can never feed a current `_plan`;
* writes merge into whatever is on disk at write time (last writer wins per
  key) and commit via temp-file + ``os.replace`` — concurrent tuners on one
  host cannot leave a torn file.

The location is ``$REPRO_TUNE_CACHE`` when set, else
``~/.cache/repro/tune.json``. Trace-time lookups go through the memoised
`lookup` so a training run touches the file at most once per process.
"""
from __future__ import annotations

import functools
import json
import os
import tempfile
from typing import Any, Dict, Optional, Sequence

SCHEMA = "repro-tune/v1"

_ENV_VAR = "REPRO_TUNE_CACHE"


def cache_path(path: Optional[str] = None) -> str:
    if path:
        return path
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "tune.json"
    )


def make_key(
    kernel: str, shape: Sequence[int], dtype: str, platform: str
) -> str:
    dims = "x".join(str(int(d)) for d in shape)
    return f"{kernel}|{dims}|{dtype}|{platform}"


def load_cache(path: Optional[str] = None) -> Dict[str, Dict[str, Any]]:
    """Entries of the on-disk cache; {} for missing/corrupt/foreign files."""
    p = cache_path(path)
    try:
        with open(p) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(raw, dict) or raw.get("schema") != SCHEMA:
        return {}  # stale layout: every key under it is untrusted
    entries = raw.get("entries")
    if not isinstance(entries, dict):
        return {}
    return {
        k: v for k, v in entries.items()
        if isinstance(k, str) and isinstance(v, dict)
    }


def save_entries(
    entries: Dict[str, Dict[str, Any]], path: Optional[str] = None
) -> str:
    """Merge `entries` into the cache file atomically; returns the path."""
    p = cache_path(path)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    merged = load_cache(p)
    merged.update(entries)
    fd, tmp = tempfile.mkstemp(
        dir=os.path.dirname(p) or ".", suffix=".tune.tmp"
    )
    try:
        with os.fdopen(fd, "w") as f:
            json.dump({"schema": SCHEMA, "entries": merged}, f, indent=2,
                      sort_keys=True)
        os.replace(tmp, p)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    clear_memo()
    return p


@functools.lru_cache(maxsize=None)
def _cached_entries(path: str) -> tuple:
    return tuple(sorted(load_cache(path).items()))


@functools.lru_cache(maxsize=None)
def lookup(
    kernel: str,
    shape: tuple,
    dtype: str,
    platform: str,
    path: Optional[str] = None,
) -> Optional[Dict[str, Any]]:
    """Trace-time plan lookup (memoised; at most one disk read per path)."""
    entries = dict(_cached_entries(cache_path(path)))
    return entries.get(make_key(kernel, shape, dtype, platform))


def clear_memo() -> None:
    """Drop the in-process memo (tests; after external cache edits)."""
    lookup.cache_clear()
    _cached_entries.cache_clear()
