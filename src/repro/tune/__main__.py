"""CLI: populate / inspect the kernel block-plan cache.

    python -m repro.tune --show
    python -m repro.tune --flash 512x64 --flash 1024x64 --dtype bfloat16
    python -m repro.tune --matmul 1024x1024x1024 --adam 1024x1024
    python -m repro.tune --flash 512x64 --measure   # force timings off-TPU

With no plan arguments, tunes the repo's benchmarked smoke shapes (the
`kernels_vs_xla` rows), so one bare invocation primes the cache a CI or
training run will read. Measured timing is the default backend on TPU only;
elsewhere the analytical cost model runs unless ``--measure`` is forced.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro import tune

# the kernels_vs_xla smoke shapes — what CI benchmarks and therefore the
# most useful default population set
DEFAULT_FLASH = ((256, 16), (512, 64))
DEFAULT_MATMUL = ((64, 64, 64), (256, 256, 256))
DEFAULT_ADAM = ((64, 64), (1024, 1024))


def _dims(spec: str, n: int, flag: str) -> List[int]:
    parts = spec.lower().split("x")
    if len(parts) != n or not all(p.isdigit() for p in parts):
        raise SystemExit(f"{flag} wants {n} x-separated ints, got {spec!r}")
    return [int(p) for p in parts]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Kernel block autotuner: populate/inspect the plan cache",
    )
    ap.add_argument("--show", action="store_true",
                    help="print the cache and exit")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="cache file (default: $REPRO_TUNE_CACHE or "
                         "~/.cache/repro/tune.json)")
    ap.add_argument("--flash", action="append", default=[], metavar="SxDH",
                    help="tune flash attention at seq x head_dim")
    ap.add_argument("--matmul", action="append", default=[], metavar="MxNxK")
    ap.add_argument("--adam", action="append", default=[], metavar="RxC",
                    help="tune the fused Adam-scale tile at rows x cols")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--measure", action="store_true",
                    help="force the measured backend (default on TPU; "
                         "off-TPU timings measure interpret mode, not Mosaic)")
    ap.add_argument("--cost-model", action="store_true",
                    help="force the analytical backend even on TPU")
    args = ap.parse_args(argv)

    if args.show:
        entries = tune.load_cache(args.cache)
        print(f"# {tune.cache_path(args.cache)} — {len(entries)} entries")
        for key in sorted(entries):
            plan = entries[key]
            blocks = {k: v for k, v in plan.items()
                      if k.startswith("block_")}
            est = plan.get("us", plan.get("cost_s"))
            print(f"{key}: {blocks} backend={plan.get('backend')} est={est}")
        return 0

    measured: Optional[bool] = None
    if args.measure:
        measured = True
    if args.cost_model:
        measured = False

    flash = [tuple(_dims(s, 2, "--flash")) for s in args.flash]
    matmul = [tuple(_dims(s, 3, "--matmul")) for s in args.matmul]
    adam = [tuple(_dims(s, 2, "--adam")) for s in args.adam]
    if not (flash or matmul or adam):
        flash, matmul, adam = (
            list(DEFAULT_FLASH), list(DEFAULT_MATMUL), list(DEFAULT_ADAM)
        )

    for S, dh in flash:
        plan = tune.tune_flash(
            S, dh, dtype=args.dtype, measured=measured, path=args.cache,
        )
        print(f"flash {S}x{dh} ({args.dtype}): bq={plan['block_q']} "
              f"bk={plan['block_k']} [{plan['backend']}]")
    for m, n, k in matmul:
        plan = tune.tune_matmul(m, n, k, dtype=args.dtype, path=args.cache)
        print(f"matmul {m}x{n}x{k} ({args.dtype}): "
              f"bm={plan['block_m']} bn={plan['block_n']} "
              f"bk={plan['block_k']} [{plan['backend']}]")
    for r, c in adam:
        plan = tune.tune_adam_scale(r, c, dtype=args.dtype, path=args.cache)
        print(f"adam_scale {r}x{c} ({args.dtype}): br={plan['block_r']} "
              f"bc={plan['block_c']} [{plan['backend']}]")
    print(f"cache -> {tune.cache_path(args.cache)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
