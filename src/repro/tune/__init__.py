"""Kernel block autotuner: cost-model + measured tuning, persistent cache.

Two halves:

* **Populate** (`tune_flash` / `tune_matmul` / `tune_adam_scale`, or the
  ``python -m repro.tune`` CLI): rank candidate tile plans — analytically
  via `cost_model` everywhere, empirically via `measure` on a TPU host —
  and store winners in the JSON cache (`cache.py`).
* **Consume** (`kernel_plan`): a read-only, memoised lookup that
  `kernels/flash.py::_plan` and `kernels/ops.py` call at trace time when
  the caller did not pin block sizes. A miss returns None and the kernels
  fall back to their static defaults (full-operand tiles in interpret mode,
  128-aligned MXU tiles compiled), so the cache is never a correctness or
  availability dependency.

Plans are keyed by ``(kernel, shape, dtype, platform)`` — a cache populated
on a TPU host never leaks into CPU interpret runs and vice versa.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax

from repro.tune.cache import (  # noqa: F401
    SCHEMA,
    cache_path,
    clear_memo,
    load_cache,
    lookup,
    make_key,
    save_entries,
)
from repro.tune.cost_model import (  # noqa: F401
    best_elementwise_plan,
    best_flash_plan,
    best_matmul_plan,
    candidate_blocks,
)

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2}


def platform_name() -> str:
    return jax.default_backend()


def kernel_plan(
    kernel: str,
    shape: Sequence[int],
    dtype: str = "float32",
    platform: Optional[str] = None,
    path: Optional[str] = None,
) -> Optional[Dict[str, Any]]:
    """Cached plan for `(kernel, shape, dtype, platform)`, or None.

    Read-only: trace-time kernel code must never write the cache (a `jit`
    trace racing a tuner write would be order-dependent)."""
    return lookup(
        kernel, tuple(int(d) for d in shape), dtype,
        platform or platform_name(), path,
    )


def tune_flash(
    S: int,
    dh: int,
    *,
    batch_heads: int = 1,
    dtype: str = "float32",
    causal: bool = True,
    measured: Optional[bool] = None,
    path: Optional[str] = None,
    write: bool = True,
) -> Dict[str, Any]:
    """Pick and (by default) persist the flash fwd+bwd block plan.

    ``measured=None`` auto-selects: real timings on TPU, the analytical
    cost model everywhere else (interpret-mode timings tune the Python
    interpreter, not Mosaic — DESIGN.md §11 known limits).
    """
    platform = platform_name()
    if measured is None:
        measured = platform == "tpu"
    if measured:
        from repro.tune.measure import best_flash_plan_measured

        plan = best_flash_plan_measured(
            S, dh, batch_heads=batch_heads, dtype=dtype, causal=causal,
        )
    else:
        plan = best_flash_plan(
            S, dh, batch_heads=batch_heads,
            dtype_bytes=_DTYPE_BYTES.get(dtype, 4), causal=causal,
            platform=platform,
        )
    if write:
        save_entries(
            {make_key("flash", (S, dh), dtype, platform): plan}, path
        )
    return plan


def tune_matmul(
    m: int, n: int, k: int, *, dtype: str = "float32",
    path: Optional[str] = None, write: bool = True,
) -> Dict[str, Any]:
    platform = platform_name()
    plan = best_matmul_plan(
        m, n, k, dtype_bytes=_DTYPE_BYTES.get(dtype, 4), platform=platform
    )
    if write:
        save_entries(
            {make_key("matmul", (m, n, k), dtype, platform): plan}, path
        )
    return plan


def tune_adam_scale(
    rows: int, cols: int, *, dtype: str = "float32",
    path: Optional[str] = None, write: bool = True,
) -> Dict[str, Any]:
    platform = platform_name()
    plan = best_elementwise_plan(
        rows, cols, dtype_bytes=_DTYPE_BYTES.get(dtype, 4), platform=platform
    )
    if write:
        save_entries(
            {make_key("adam_scale", (rows, cols), dtype, platform): plan},
            path,
        )
    return plan
