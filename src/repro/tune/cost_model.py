"""Analytical block-plan cost model (VMEM footprint + roofline terms).

Ranks candidate tile plans without running anything, reusing the hardware
constants from `launch/roofline.py` (TPU v5e: 197 TFLOP/s bf16, 819 GB/s
HBM). The per-plan estimate is the optimistic-overlap roofline time plus a
per-grid-step launch overhead:

    cost(plan) = max(flops / PEAK_FLOPS, hbm_bytes / HBM_BW)
                 + grid_steps * step_overhead(platform)

The overhead term is what actually separates plans at a fixed problem size:
total FLOPs are plan-independent, and HBM traffic only varies with how often
K/V tiles are re-streamed, so the model reduces to "stream as few tiles as
fit". On TPU the step overhead is small (Mosaic pipelines the grid) and the
binding constraint is the ~16 MiB VMEM budget (`pallas_guide`: blocks must
fit q/k/v/o tiles + f32 scratch in VMEM, second-to-last tile dim >= 8 for
f32). In interpret mode (CPU validation path) each grid step is a Python
interpreter iteration costing ~1e-4 s, which dominates everything — the
model correctly collapses to "one grid step over the whole operand", the
empirical ~30x win that `kernels/ops.py::_interp_blocks` hardcoded before.

Off-TPU this cost model is the ONLY tuning backend (measuring interpret-mode
kernels says nothing about Mosaic); on TPU `repro.tune.measure` overrides it
with real timings (DESIGN.md §11 known limits).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.launch.roofline import HBM_BW, PEAK_FLOPS

VMEM_BYTES = 16 * 1024 * 1024
VMEM_BUDGET = 0.75  # leave headroom for Mosaic's own buffers
MIN_BLOCK = 8  # f32 min sublane tile
# one grid step in interpret mode is a traced Python iteration; on TPU the
# grid is pipelined and a step costs roughly a VMEM tile swap
INTERPRET_STEP_OVERHEAD_S = 1e-4
TPU_STEP_OVERHEAD_S = 1e-7


def _pow2_range(lo: int, hi: int) -> List[int]:
    out, b = [], lo
    while b <= hi:
        out.append(b)
        b *= 2
    return out


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def step_overhead_s(platform: str) -> float:
    return TPU_STEP_OVERHEAD_S if platform == "tpu" else INTERPRET_STEP_OVERHEAD_S


def candidate_blocks(size: int, cap: Optional[int] = None) -> List[int]:
    """Power-of-two block sizes for one dimension of extent `size`: MIN_BLOCK
    up to the padded full extent (the full-tile plan is always a candidate)."""
    hi = max(MIN_BLOCK, _next_pow2(size))
    if cap is not None:
        hi = min(hi, max(MIN_BLOCK, cap))
    return _pow2_range(MIN_BLOCK, hi)


def flash_vmem_bytes(bq: int, bk: int, dh: int, dtype_bytes: int) -> int:
    """Resident VMEM for one flash fwd/bwd grid step: q/o tiles (bq, dh),
    k/v/dk/dv tiles (bk, dh), the (bq, bk) score tile and f32 scratch."""
    tiles = 2 * bq * dh + 4 * bk * dh  # q, o, k, v, dk, dv
    score = bq * bk
    scratch = 4 * (2 * (bq * 1) + bq * dh + 2 * bk * dh)  # m, l, acc (f32)
    return tiles * dtype_bytes + score * 4 + scratch


def flash_plan_cost(
    S: int,
    dh: int,
    bq: int,
    bk: int,
    *,
    batch_heads: int = 1,
    dtype_bytes: int = 4,
    causal: bool = True,
    platform: str = "cpu",
) -> float:
    """Estimated seconds for flash attention forward + backward at one
    (block_q, block_k) plan; `inf` when the plan exceeds the VMEM budget."""
    if platform == "tpu" and (
        flash_vmem_bytes(bq, bk, dh, dtype_bytes) > VMEM_BUDGET * VMEM_BYTES
    ):
        return float("inf")
    Sp = -(-S // max(bq, bk)) * max(bq, bk)
    q_steps, k_steps = Sp // bq, Sp // bk
    # causal tiles below the diagonal never contribute but are still visited
    # (the kernels do not early-exit), so only the FLOP term shrinks
    tile_frac = 0.5 + 0.5 / max(q_steps, k_steps) if causal else 1.0
    # fwd: qk^T + pv; bwd: recompute qk^T + dq/dk/dv matmuls (~3x fwd)
    flops = 4.0 * (2 * Sp * Sp * dh) * tile_frac * batch_heads
    # q/o/do tiles load once per row block (held across the inner loop);
    # k/v stream once per (iq, ik) tile in fwd and twice in bwd (dq + dkv)
    q_bytes = 4 * Sp * dh * dtype_bytes
    kv_bytes = 3 * q_steps * (2 * Sp * dh) * dtype_bytes
    hbm = (q_bytes + kv_bytes) * batch_heads
    grid_steps = 3 * batch_heads * q_steps * k_steps  # fwd + dq + dkv calls
    return max(flops / PEAK_FLOPS, hbm / HBM_BW) + grid_steps * step_overhead_s(
        platform
    )


def best_flash_plan(
    S: int,
    dh: int,
    *,
    batch_heads: int = 1,
    dtype_bytes: int = 4,
    causal: bool = True,
    platform: str = "cpu",
) -> Dict[str, int]:
    """argmin over the candidate (block_q, block_k) grid; deterministic
    tie-break toward larger blocks (fewer grid steps)."""
    best, best_cost = None, float("inf")
    for bq in candidate_blocks(S):
        for bk in candidate_blocks(S):
            c = flash_plan_cost(
                S, dh, bq, bk, batch_heads=batch_heads,
                dtype_bytes=dtype_bytes, causal=causal, platform=platform,
            )
            if c < best_cost or (
                c == best_cost and best is not None
                and bq * bk > best[0] * best[1]
            ):
                best, best_cost = (bq, bk), c
    assert best is not None, "candidate grid cannot be empty"
    return {"block_q": best[0], "block_k": best[1],
            "cost_s": best_cost, "backend": "cost_model"}


def matmul_vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int) -> int:
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes + bm * bn * 4


def matmul_plan_cost(
    m: int, n: int, k: int, bm: int, bn: int, bk: int,
    *, dtype_bytes: int = 4, platform: str = "cpu",
) -> float:
    """Estimated seconds for a (m,k)x(k,n) tiled matmul at one plan."""
    if platform == "tpu" and (
        matmul_vmem_bytes(bm, bn, bk, dtype_bytes) > VMEM_BUDGET * VMEM_BYTES
    ):
        return float("inf")
    gm, gn, gk = -(-m // bm), -(-n // bn), -(-k // bk)
    flops = 2.0 * m * n * k
    # A streams once per column block, B once per row block of the output
    hbm = (gn * m * k + gm * k * n + m * n) * dtype_bytes
    grid_steps = gm * gn * gk
    return max(flops / PEAK_FLOPS, hbm / HBM_BW) + grid_steps * step_overhead_s(
        platform
    )


def best_matmul_plan(
    m: int, n: int, k: int, *, dtype_bytes: int = 4, platform: str = "cpu"
) -> Dict[str, int]:
    best, best_cost = None, float("inf")
    for bm in candidate_blocks(m):
        for bn in candidate_blocks(n):
            for bk in candidate_blocks(k):
                c = matmul_plan_cost(
                    m, n, k, bm, bn, bk,
                    dtype_bytes=dtype_bytes, platform=platform,
                )
                if c < best_cost or (
                    c == best_cost and best is not None
                    and bm * bn * bk > best[0] * best[1] * best[2]
                ):
                    best, best_cost = (bm, bn, bk), c
    assert best is not None
    return {"block_m": best[0], "block_n": best[1], "block_k": best[2],
            "cost_s": best_cost, "backend": "cost_model"}


def best_elementwise_plan(
    rows: int, cols: int, *, dtype_bytes: int = 4, platform: str = "cpu",
    operands: int = 5,
) -> Dict[str, int]:
    """Tile plan for elementwise kernels (fused Adam scale): pure HBM-bound,
    so the model is grid overhead vs the VMEM budget on `operands` tiles."""
    best, best_cost = None, float("inf")
    for br in candidate_blocks(rows):
        for bc in candidate_blocks(cols):
            if platform == "tpu" and (
                operands * br * bc * max(dtype_bytes, 4)
                > VMEM_BUDGET * VMEM_BYTES
            ):
                continue
            gr, gc = -(-rows // br), -(-cols // bc)
            hbm = operands * rows * cols * dtype_bytes
            c = hbm / HBM_BW + gr * gc * step_overhead_s(platform)
            if c < best_cost or (
                c == best_cost and best is not None
                and br * bc > best[0] * best[1]
            ):
                best, best_cost = (br, bc), c
    assert best is not None
    return {"block_r": best[0], "block_c": best[1],
            "cost_s": best_cost, "backend": "cost_model"}
