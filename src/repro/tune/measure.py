"""Measured-timing tuner backend: warmup + median-of-k on live hardware.

This is the backend that takes over from the cost model on a real TPU host
(`python -m repro.tune --measure`, the default when
``jax.default_backend() == "tpu"``): every candidate plan is compiled and
timed on device, and the cache records the empirical winner. Off-TPU the
kernels only run in interpret mode, where timings measure the Python
interpreter rather than Mosaic — measuring there would tune for the wrong
machine, so the CLI refuses unless ``--measure`` is forced explicitly.
"""
from __future__ import annotations

import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp


def measure_us(
    fn: Callable, *args, iters: int = 10, warmup: int = 2
) -> float:
    """Median microseconds per call; compile + warmup excluded, every timed
    call individually synchronised with `block_until_ready`."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    samples: List[float] = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return 1e6 * statistics.median(samples)


def _flash_inputs(S: int, dh: int, batch_heads: int, dtype: str):
    shape = (1, batch_heads, S, dh)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q, k, v, do = (
        jax.random.normal(kk, shape).astype(dtype) for kk in ks
    )
    return q, k, v, do


def measure_flash_plan(
    S: int,
    dh: int,
    bq: int,
    bk: int,
    *,
    batch_heads: int = 1,
    dtype: str = "float32",
    causal: bool = True,
    interpret: Optional[bool] = None,
    iters: int = 10,
    warmup: int = 2,
) -> float:
    """Measured fwd+bwd microseconds for one flash (block_q, block_k) plan."""
    from repro.kernels import ops

    q, k, v, do = _flash_inputs(S, dh, batch_heads, dtype)

    def fwd(q, k, v):
        return ops.attention(
            q, k, v, causal=causal, block_q=bq, block_k=bk,
            interpret=interpret,
        )

    grad = jax.jit(jax.grad(
        lambda q, k, v: jnp.sum(fwd(q, k, v).astype(jnp.float32) * do),
        argnums=(0, 1, 2),
    ))
    us_f = measure_us(jax.jit(fwd), q, k, v, iters=iters, warmup=warmup)
    us_b = measure_us(grad, q, k, v, iters=iters, warmup=warmup)
    return us_f + us_b


def best_flash_plan_measured(
    S: int,
    dh: int,
    *,
    batch_heads: int = 1,
    dtype: str = "float32",
    causal: bool = True,
    interpret: Optional[bool] = None,
    iters: int = 10,
    warmup: int = 2,
) -> Dict[str, Any]:
    """Time every candidate (block_q, block_k) pair; return the winner."""
    from repro.tune.cost_model import candidate_blocks

    best: Optional[Dict[str, Any]] = None
    for bq in candidate_blocks(S):
        for bk in candidate_blocks(S):
            try:
                us = measure_flash_plan(
                    S, dh, bq, bk, batch_heads=batch_heads, dtype=dtype,
                    causal=causal, interpret=interpret, iters=iters,
                    warmup=warmup,
                )
            except Exception:  # plan rejected by the compiler (VMEM, tiling)
                continue
            if best is None or us < best["us"]:
                best = {"block_q": bq, "block_k": bk, "us": us,
                        "backend": "measured"}
    if best is None:
        raise RuntimeError(
            f"no flash plan compiled for S={S}, dh={dh} on this backend"
        )
    return best
