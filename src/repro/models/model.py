"""Whole-model API: init, training forward/loss, and single-token decode.

Layers are organised as ``num_superblocks`` repetitions of the config's
``pattern``; parameters for each pattern position are stacked along a leading
axis and the superblocks are traversed with ``jax.lax.scan`` so the compiled
HLO stays O(pattern) instead of O(num_layers) — essential to make the 60-layer
dry-runs lower in reasonable time.

Modality frontends (ViT patch embedder for the VLM, EnCodec for audio) are
stubs per the brief: ``input_specs`` in the launch layer provides precomputed
embeddings of the right shape; here we only own the projector that maps them
into d_model and the multi-codebook embedding/head for audio.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    apply_norm,
    dense_init,
    embed_init,
    init_norm,
)
from repro.models.transformer import (
    block_decode,
    block_train,
    init_block,
    init_block_cache,
)

IGNORE_INDEX = -100


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig) -> Dict:
    dtype = cfg.params_dtype
    keys = jax.random.split(key, 6 + len(cfg.pattern))
    V = cfg.vocab_size

    params: Dict = {}
    if cfg.num_codebooks > 1:
        params["embed"] = {
            "embedding": embed_init(keys[0], (cfg.num_codebooks, V, cfg.d_model), dtype)
        }
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.num_codebooks * V), dtype)
    else:
        params["embed"] = {"embedding": embed_init(keys[0], (V, cfg.d_model), dtype)}
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[1], (cfg.d_model, V), dtype)

    if cfg.learnable_pos_emb:
        params["pos_emb"] = embed_init(keys[2], (cfg.max_seq_len, cfg.d_model), dtype)
    if cfg.frontend is not None and cfg.frontend_dim:
        params["frontend_proj"] = dense_init(keys[3], (cfg.frontend_dim, cfg.d_model), dtype)

    n_super = cfg.num_superblocks
    if cfg.scan_layers:
        # stacked superblocks: vmap the per-block init over n_super keys
        blocks = []
        for p_idx, spec in enumerate(cfg.pattern):
            bkeys = jax.random.split(keys[6 + p_idx], n_super)
            blocks.append(jax.vmap(lambda k: init_block(k, cfg, spec))(bkeys))
        params["blocks"] = tuple(blocks)
    else:
        # one subtree per layer (layer l = pattern[l % len(pattern)])
        lkeys = jax.random.split(keys[6], cfg.num_layers)
        params["blocks"] = tuple(
            init_block(lkeys[l], cfg, cfg.pattern[l % len(cfg.pattern)])
            for l in range(cfg.num_layers)
        )
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def cast_params(params, dtype):
    """Mixed precision: fp32 master weights -> compute-dtype copies at use."""
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params,
    )


def constrain_batch(x: jnp.ndarray, seq_sharded: bool = False) -> jnp.ndarray:
    """Pin the leading (batch) dim of an activation to the data axes.

    The `data` mesh axis is shared between batch parallelism and FSDP weight
    sharding; without explicit constraints GSPMD sometimes resolves the
    conflict by replicating activations — catastrophic at 1M-token batches.
    No-op outside a mesh context or when the batch doesn't divide.

    ``seq_sharded=True`` additionally shards the sequence dim over `model`
    (sequence parallelism): GSPMD then lowers the tensor-parallel activation
    all-reduces around each block to reduce-scatter + all-gather pairs.
    """
    try:
        from jax.sharding import PartitionSpec as P

        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if not axes:
            return x
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if total <= 1 or x.shape[0] % total != 0:
            return x
        rest = [None] * (x.ndim - 1)
        if (
            seq_sharded
            and x.ndim >= 2
            and "model" in mesh.axis_names
            and x.shape[1] % mesh.shape["model"] == 0
        ):
            rest[0] = "model"
        return jax.lax.with_sharding_constraint(x, P(axes, *rest))
    except Exception:  # pragma: no cover — sharding context unavailable
        return x


# ---------------------------------------------------------------------------
# Embedding / head helpers
# ---------------------------------------------------------------------------


def _embed(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    dtype = cfg.compute_dtype
    emb = params["embed"]["embedding"]
    if cfg.num_codebooks > 1:
        # tokens: (B, S, K); sum codebook embeddings (MusicGen-style)
        parts = [emb[k][tokens[..., k]] for k in range(cfg.num_codebooks)]
        return sum(parts).astype(dtype)
    return emb[tokens].astype(dtype)


def _logits(params: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    dt = jnp.float32 if cfg.logits_fp32 else cfg.compute_dtype
    if cfg.num_codebooks > 1:
        out = x.astype(dt) @ params["lm_head"].astype(dt)
        return out.reshape(*x.shape[:-1], cfg.num_codebooks, cfg.vocab_size)
    head = params["embed"]["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    return x.astype(dt) @ head.astype(dt)


def _run_blocks_train(params: Dict, cfg: ModelConfig, x: jnp.ndarray):
    if not cfg.scan_layers:
        aux = jnp.zeros((), jnp.float32)
        for l, bp in enumerate(params["blocks"]):
            x, a = block_train(bp, x, cfg, cfg.pattern[l % len(cfg.pattern)])
            aux = aux + a
        return x, aux

    def body(carry, stacked):
        h, aux = carry
        h = constrain_batch(h, cfg.seq_sharded)
        for spec, bp in zip(cfg.pattern, stacked):
            h, a = block_train(bp, h, cfg, spec)
            aux = aux + a
        return (constrain_batch(h, cfg.seq_sharded), aux), None

    # activation checkpointing: only the (B,S,d) boundary activations are
    # saved; attention/score matrices are recomputed in the backward pass
    if cfg.remat_policy == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    else:
        body = jax.checkpoint(body)
    unroll = cfg.num_superblocks if cfg.scan_unroll else 1
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["blocks"], unroll=unroll
    )
    return x, aux


# ---------------------------------------------------------------------------
# Training forward / loss
# ---------------------------------------------------------------------------


def forward_train(
    params: Dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    frontend_embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits, aux_loss). tokens: (B,S) or (B,S,K) for audio."""
    params = cast_params(params, cfg.compute_dtype)
    x = _embed(params, cfg, tokens)
    if frontend_embeds is not None:
        pref = frontend_embeds.astype(cfg.compute_dtype)
        if "frontend_proj" in params:
            pref = pref @ params["frontend_proj"]
        x = jnp.concatenate([pref, x], axis=1)
    if cfg.learnable_pos_emb:
        x = x + params["pos_emb"][: x.shape[1]].astype(x.dtype)

    x, aux = _run_blocks_train(params, cfg, x)
    x = apply_norm(params["final_norm"], x)
    if frontend_embeds is not None:
        x = x[:, frontend_embeds.shape[1] :]  # logits only over text positions
    return _logits(params, cfg, x), aux


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over non-ignored positions. logits: (..., V), labels: (...)."""
    V = logits.shape[-1]
    valid = labels != IGNORE_INDEX
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


def forward_features(
    params: Dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    frontend_embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Final-norm hidden states (before the LM head). Returns (x, aux)."""
    params = cast_params(params, cfg.compute_dtype)
    x = _embed(params, cfg, tokens)
    if frontend_embeds is not None:
        pref = frontend_embeds.astype(cfg.compute_dtype)
        if "frontend_proj" in params:
            pref = pref @ params["frontend_proj"]
        x = jnp.concatenate([pref, x], axis=1)
    if cfg.learnable_pos_emb:
        x = x + params["pos_emb"][: x.shape[1]].astype(x.dtype)
    x, aux = _run_blocks_train(params, cfg, x)
    x = apply_norm(params["final_norm"], x)
    if frontend_embeds is not None:
        x = x[:, frontend_embeds.shape[1] :]
    return x, aux


def _chunked_ce(params: Dict, cfg: ModelConfig, x: jnp.ndarray, labels: jnp.ndarray):
    """CE over sequence chunks: the (B,S,V) logits are never materialised.

    Each chunk's logits+CE are rematerialised in the backward pass, so the
    peak holds one (B, chunk, V) block instead of the full tensor.
    """
    B, S, _ = x.shape
    chunk = cfg.loss_chunk
    n = S // chunk
    xc = jnp.moveaxis(x[:, : n * chunk].reshape(B, n, chunk, -1), 1, 0)
    lc = jnp.moveaxis(labels[:, : n * chunk].reshape(B, n, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, args):
        xb, lb = args
        logits = _logits(params, cfg, xb)
        valid = lb != IGNORE_INDEX
        safe = jnp.where(valid, lb, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        s, c = carry
        return (s + jnp.sum(jnp.where(valid, nll, 0.0)), c + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (xc, lc))
    rem = S - n * chunk
    if rem:  # tail chunk (shapes are static)
        logits = _logits(params, cfg, x[:, n * chunk :])
        tail = cross_entropy(logits, labels[:, n * chunk :])
        tot = tot + tail * jnp.maximum(jnp.sum(labels[:, n * chunk :] != IGNORE_INDEX), 1)
        cnt = cnt + jnp.sum(labels[:, n * chunk :] != IGNORE_INDEX)
    return tot / jnp.maximum(cnt, 1)


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    tokens, labels = batch["tokens"], batch["labels"]
    use_chunked = (
        cfg.loss_chunk > 0
        and cfg.num_codebooks == 1
        and tokens.ndim == 2
        and tokens.shape[1] >= 2 * cfg.loss_chunk
    )
    if use_chunked:
        x, aux = forward_features(params, cfg, tokens, batch.get("frontend"))
        ce = _chunked_ce(params, cfg, x, labels)
    else:
        logits, aux = forward_train(params, cfg, tokens, batch.get("frontend"))
        ce = cross_entropy(logits, labels)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Tuple:
    """Cache pytree mirroring params['blocks'] structure."""
    dtype = cfg.compute_dtype
    if not cfg.scan_layers:
        return tuple(
            init_block_cache(cfg, cfg.pattern[l % len(cfg.pattern)], batch, seq_len, dtype)
            for l in range(cfg.num_layers)
        )
    caches = []
    for spec in cfg.pattern:
        one = init_block_cache(cfg, spec, batch, seq_len, dtype)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_superblocks,) + a.shape), one
        )
        caches.append(stacked)
    return tuple(caches)


def forward_decode(
    params: Dict,
    cfg: ModelConfig,
    token: jnp.ndarray,
    cache: Tuple,
    pos: jnp.ndarray,
) -> Tuple[jnp.ndarray, Tuple]:
    """token: (B,1) or (B,1,K); pos: scalar int32. Returns (logits, cache)."""
    params = cast_params(params, cfg.compute_dtype)
    x = _embed(params, cfg, token)
    if cfg.learnable_pos_emb:
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_emb"], pos, 1, 0).astype(x.dtype)

    if not cfg.scan_layers:
        new_cache = []
        for l, (bp, bc) in enumerate(zip(params["blocks"], cache)):
            x, nc = block_decode(bp, x, bc, pos, cfg, cfg.pattern[l % len(cfg.pattern)])
            new_cache.append(nc)
        x = apply_norm(params["final_norm"], x)
        return _logits(params, cfg, x), tuple(new_cache)

    def body(h, stacked):
        bps, bcs = stacked
        new_cs = []
        for spec, bp, bc in zip(cfg.pattern, bps, bcs):
            h, nc = block_decode(bp, h, bc, pos, cfg, spec)
            new_cs.append(nc)
        return h, tuple(new_cs)

    x, new_cache = jax.lax.scan(
        body, x, (params["blocks"], cache),
        unroll=cfg.num_superblocks if cfg.scan_unroll else 1,
    )
    x = apply_norm(params["final_norm"], x)
    return _logits(params, cfg, x), new_cache
