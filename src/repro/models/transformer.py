"""Block-level composition: one decoder block = norm -> mixer -> norm -> MLP,
where the mixer is attention (GQA/MLA), Mamba, mLSTM or sLSTM, and the MLP is
dense, MoE, or absent (xLSTM blocks integrate their own feed-forward).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import xlstm as xl
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.moe import apply_moe, init_moe


def init_block(key, cfg: ModelConfig, spec: BlockSpec) -> dict:
    kn1, kmix, kn2, kmlp = jax.random.split(key, 4)
    dtype = cfg.params_dtype
    p = {"norm1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["mixer"] = attn.init_attention(kmix, cfg.d_model, cfg.attention, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mb.init_mamba(kmix, cfg.d_model, cfg.ssm, dtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = xl.init_mlstm(kmix, cfg.d_model, cfg.ssm, dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = xl.init_slstm(kmix, cfg.d_model, cfg.ssm, dtype)
    else:
        raise ValueError(f"unknown mixer {spec.mixer}")

    if spec.mlp != "none":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if spec.mlp == "moe":
            p["mlp"] = init_moe(kmlp, cfg.d_model, cfg.d_ff, cfg.moe, cfg.mlp_act, dtype)
        else:
            p["mlp"] = init_mlp(kmlp, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    return p


def block_train(
    params: dict, x: jnp.ndarray, cfg: ModelConfig, spec: BlockSpec
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(params["norm1"], x)
    if spec.mixer == "attn":
        h = attn.gqa_train(params["mixer"], h, cfg.attention,
                           use_kernels=cfg.use_kernels) \
            if cfg.attention.kind == "gqa" \
            else attn.mla_train(params["mixer"], h, cfg.attention)
    elif spec.mixer == "mamba":
        h = mb.mamba_train(params["mixer"], h, cfg.ssm)
    elif spec.mixer == "mlstm":
        h = xl.mlstm_train(params["mixer"], h, cfg.ssm)
    else:  # slstm
        h = xl.slstm_train(params["mixer"], h, cfg.ssm)
    x = x + h

    if spec.mlp != "none":
        h = apply_norm(params["norm2"], x)
        if spec.mlp == "moe":
            h, aux = apply_moe(params["mlp"], h, cfg.moe)
        else:
            h = apply_mlp(params["mlp"], h)
        x = x + h
    return x, aux


def init_block_cache(
    cfg: ModelConfig, spec: BlockSpec, batch: int, seq_len: int, dtype
) -> dict:
    if spec.mixer == "attn":
        if cfg.attention.kind == "mla":
            return attn.init_mla_cache(batch, seq_len, cfg.attention, dtype)
        return attn.init_gqa_cache(batch, seq_len, cfg.attention, dtype)
    if spec.mixer == "mamba":
        return mb.init_mamba_cache(batch, cfg.d_model, cfg.ssm, dtype)
    if spec.mixer == "mlstm":
        return xl.init_mlstm_cache(batch, cfg.d_model, cfg.ssm, dtype)
    return xl.init_slstm_cache(batch, cfg.d_model, cfg.ssm, dtype)


def block_decode(
    params: dict,
    x: jnp.ndarray,
    cache: dict,
    pos: jnp.ndarray,
    cfg: ModelConfig,
    spec: BlockSpec,
) -> Tuple[jnp.ndarray, dict]:
    h = apply_norm(params["norm1"], x)
    if spec.mixer == "attn":
        if cfg.attention.kind == "mla":
            h, cache = attn.mla_decode(params["mixer"], h, cache, pos, cfg.attention)
        else:
            h, cache = attn.gqa_decode(params["mixer"], h, cache, pos, cfg.attention)
    elif spec.mixer == "mamba":
        h, cache = mb.mamba_decode(params["mixer"], h, cache, cfg.ssm)
    elif spec.mixer == "mlstm":
        h, cache = xl.mlstm_decode(params["mixer"], h, cache, cfg.ssm)
    else:
        h, cache = xl.slstm_decode(params["mixer"], h, cache, cfg.ssm)
    x = x + h

    if spec.mlp != "none":
        h = apply_norm(params["norm2"], x)
        if spec.mlp == "moe":
            h, _ = apply_moe(params["mlp"], h, cfg.moe)
        else:
            h = apply_mlp(params["mlp"], h)
        x = x + h
    return x, cache
