"""Mixture-of-Experts MLP with TPU-native capacity-based dispatch.

Routing follows the Megatron/GSPMD dispatch-combine idiom: tokens are
dispatched to per-expert buffers of fixed capacity with one-hot einsums, the
experts run as a single batched (vmapped-weights) matmul that shards cleanly
over the `expert`/`model` mesh axis, and results are combined with the gate
weights. This keeps the compiled HLO free of gathers/scatters (which lower
poorly on TPU) and makes the all-to-all pattern explicit for the roofline.

Supports DeepSeek-style shared experts (always-on) alongside routed experts
and the standard switch-transformer load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import apply_mlp, dense_init, init_mlp


def init_moe(key, d_model: int, d_ff: int, cfg: MoEConfig, act: str, dtype) -> dict:
    d_ff_e = cfg.d_ff_expert or d_ff
    k_router, k_experts, k_shared = jax.random.split(key, 3)
    E = cfg.num_experts
    eks = jax.random.split(k_experts, 3)
    scale = 1.0 / math.sqrt(d_model)
    params = {
        "router": dense_init(k_router, (d_model, E), jnp.float32),
        # stacked expert weights: leading axis = expert
        "w_gate_e": dense_init(eks[0], (E, d_model, d_ff_e), dtype, scale),
        "w_up_e": dense_init(eks[1], (E, d_model, d_ff_e), dtype, scale),
        "w_down_e": dense_init(eks[2], (E, d_ff_e, d_model), dtype, 1.0 / math.sqrt(d_ff_e)),
    }
    if cfg.num_shared > 0:
        params["shared"] = init_mlp(k_shared, d_model, d_ff_e * cfg.num_shared, act, dtype)
    return params


def moe_capacity(num_tokens: int, cfg: MoEConfig) -> int:
    cap = int(math.ceil(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts))
    return max(cap, 4)


def _top_k_gates(logits: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (gates (T,E) with zeros off the top-k, mask (T,E) in {0,1})."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, top_idx = jax.lax.top_k(probs, k)  # (T,k)
    mask = jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=1)  # (T,E)
    gates = probs * mask
    # renormalise over selected experts (standard top-k routing)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, mask


def _group_size(T: int, target: int) -> int:
    """Largest divisor of T that is <= target (GShard group size)."""
    g = max(1, min(T, target))
    while T % g != 0:
        g -= 1
    return g


def apply_moe(
    params: dict,
    x: jnp.ndarray,
    cfg: MoEConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (output (B, S, d), aux_loss scalar).

    Tokens are routed within fixed-size *groups* (GShard): the dispatch/
    combine one-hots are (G, g, E, C_g) with per-group capacity C_g, which
    bounds the dispatch tensor to O(g * E * C_g) per group instead of
    O(T * E * C) globally — mandatory at the 1M-token train_4k scale.
    """
    B, S, D = x.shape
    T = B * S
    E = cfg.num_experts
    g = _group_size(T, cfg.group_size)
    G = T // g
    xg = x.reshape(G, g, D)

    logits = xg.astype(jnp.float32) @ params["router"]  # (G,g,E)
    gates, mask = jax.vmap(lambda lg: _top_k_gates(lg, cfg.top_k))(logits)

    # load-balance aux loss (Switch/GShard): E * mean_G sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    f = jnp.mean(mask, axis=1)  # (G,E) fraction of tokens per expert
    p = jnp.mean(probs, axis=1)
    aux = cfg.aux_loss_coef * E * jnp.mean(jnp.sum(f * p, axis=-1))

    C = moe_capacity(g, cfg)
    # position of each token within its expert buffer (per group)
    pos_in_expert = jnp.cumsum(mask, axis=1) * mask - 1.0  # (G,g,E)
    fits = (pos_in_expert < C) & (mask > 0)
    onehot_pos = jax.nn.one_hot(
        jnp.where(fits, pos_in_expert, -1).astype(jnp.int32), C, dtype=x.dtype
    )  # (G,g,E,C)
    dispatch = onehot_pos
    combine = gates.astype(x.dtype)[..., None] * onehot_pos

    # dispatch -> (G,E,C,D); in the sharded runtime this einsum lowers to the
    # expert-parallel all-to-all
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate_e"]))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, params["w_up_e"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down_e"])
    yg = jnp.einsum("gtec,gecd->gtd", combine, expert_out)

    if "shared" in params:
        yg = yg + apply_mlp(params["shared"], xg)

    return yg.reshape(B, S, D), aux
