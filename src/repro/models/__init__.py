from repro.models.model import (
    cross_entropy,
    forward_decode,
    forward_train,
    init_cache,
    init_model,
    loss_fn,
    param_count,
)

__all__ = [
    "cross_entropy",
    "forward_decode",
    "forward_train",
    "init_cache",
    "init_model",
    "loss_fn",
    "param_count",
]
