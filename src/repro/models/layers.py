"""Basic neural-net layers: norms, embeddings, rotary embeddings, MLPs.

All layers follow a functional convention: ``init_*`` returns a parameter
pytree (plain dicts of jnp arrays) and ``apply`` functions are pure.
Parameter dictionaries use short, stable key names so that sharding rules
(`repro.sharding.rules`) and the basis-rotation layout (`repro.core`) can
pattern-match on them.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in initialiser (LLM-standard)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------


def init_norm(kind: str, dim: int, dtype) -> dict:
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    if "bias" in params:  # LayerNorm
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mean) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # RMSNorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(orig_dtype)


def rms_norm_headwise(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    """Per-head RMS norm over the trailing head_dim (qk_norm)."""
    orig = x.dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(orig)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply rotary embeddings.

    x: (..., seq, head_dim); positions: (seq,) or broadcastable to x[..., :, 0].
    """
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (head_dim//2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd//2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(k1, (d_model, d_ff), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype),
    }


def apply_mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype) -> dict:
    return {"embedding": embed_init(key, (vocab, d_model), dtype)}


def embed_tokens(params: dict, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return params["embedding"][tokens].astype(dtype)


def logits_from_head(head: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    # Compute logits in fp32 for numerical stability of the softmax/CE.
    return x.astype(jnp.float32) @ head.astype(jnp.float32)
