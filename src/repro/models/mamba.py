"""Mamba (selective SSM) mixer — used by the Jamba hybrid architecture.

Training uses a parallel associative scan over time (TPU-friendly: the
recurrence h_t = A_t * h_{t-1} + b_t is a first-order linear scan, so
``jax.lax.associative_scan`` turns it into a log-depth tree of elementwise
ops). Decoding carries (conv_state, ssm_state) — O(1) memory per token, which
is what makes the 500k-token decode shape feasible for hybrid models.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init


def mamba_dims(d_model: int, cfg: SSMConfig) -> Tuple[int, int]:
    d_inner = cfg.expand * d_model
    dt_rank = cfg.dt_rank or max(1, math.ceil(d_model / 16))
    return d_inner, dt_rank


def init_mamba(key, d_model: int, cfg: SSMConfig, dtype) -> dict:
    d_inner, dt_rank = mamba_dims(d_model, cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation of A
    A = jnp.tile(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (d_inner, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[4], (d_inner,), jnp.float32)
        * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    # inverse softplus so that softplus(dt_bias) == dt_init
    dt_bias = dt_init + jnp.log1p(-jnp.exp(-dt_init))
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_inner), dtype),
        "conv_w": dense_init(ks[1], (cfg.d_conv, d_inner), dtype, 1.0 / math.sqrt(cfg.d_conv)),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], (d_inner, dt_rank + 2 * cfg.d_state), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_inner), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_inner, d_model), dtype),
    }


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,d_inner); w: (d_conv, d_inner) depthwise causal conv."""
    d_conv = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(d_conv):  # d_conv is tiny (4): unrolled taps
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + b


def _ssm_scan(dA: jnp.ndarray, dBx: jnp.ndarray) -> jnp.ndarray:
    """Associative scan of h_t = dA_t * h_{t-1} + dBx_t along axis 1 (time)."""

    def combine(a, b):
        a_l, b_l = a
        a_r, b_r = b
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    return h


# Chunk length for long sequences: the (B, S, d_inner, d_state) state tensor
# is never materialised beyond one chunk; chunks are chained by a sequential
# carry (h at chunk boundary) with rematerialisation in the backward pass.
SSM_CHUNK = 1024


def _ssm_scan_chunked(dA: jnp.ndarray, dBx: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """Memory-bounded y = (scan(dA,dBx) . C): returns (B,S,d_inner)."""
    B, S, D, N = dA.shape
    if S <= SSM_CHUNK or S % SSM_CHUNK != 0:
        h = _ssm_scan(dA, dBx)
        return jnp.einsum("bsdn,bsn->bsd", h, C)
    n_chunks = S // SSM_CHUNK

    def reshape(x):
        return jnp.moveaxis(x.reshape(B, n_chunks, SSM_CHUNK, *x.shape[2:]), 1, 0)

    dA_c, dBx_c, C_c = reshape(dA), reshape(dBx), reshape(C)

    def body(h0, args):
        a, b, c = args
        # prefix-scan within chunk, seeded by the carried boundary state
        b = b.at[:, 0].add(a[:, 0] * h0)
        h = _ssm_scan(a, b)
        y = jnp.einsum("bsdn,bsn->bsd", h, c)
        return h[:, -1], y

    h0 = jnp.zeros((B, D, N), dA.dtype)
    _, y = jax.lax.scan(jax.checkpoint(body), h0, (dA_c, dBx_c, C_c))
    return jnp.moveaxis(y, 0, 1).reshape(B, S, D)


def mamba_train(params: dict, u: jnp.ndarray, cfg: SSMConfig) -> jnp.ndarray:
    B, S, d_model = u.shape
    d_inner, dt_rank = mamba_dims(d_model, cfg)
    xz = u @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    x = jax.nn.silu(_causal_depthwise_conv(x, params["conv_w"], params["conv_b"]))

    proj = x @ params["x_proj"]
    dt, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"] + params["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(params["A_log"])  # (d_inner, d_state)

    dA = jnp.exp(dt[..., None] * A)  # (B,S,d_inner,d_state)
    dBx = (dt * x.astype(jnp.float32))[..., None] * Bmat.astype(jnp.float32)[:, :, None, :]
    y = _ssm_scan_chunked(dA, dBx, Cmat.astype(jnp.float32))
    y = y + params["D"] * x.astype(jnp.float32)
    y = y.astype(u.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"]


def init_mamba_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> dict:
    d_inner, _ = mamba_dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, cfg.d_state), jnp.float32),
    }


def mamba_decode(
    params: dict, u: jnp.ndarray, cache: dict, cfg: SSMConfig
) -> Tuple[jnp.ndarray, dict]:
    """u: (B, 1, d_model) -> (y (B,1,d_model), new cache)."""
    B, _, d_model = u.shape
    d_inner, dt_rank = mamba_dims(d_model, cfg)
    xz = u[:, 0] @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)  # (B, d_inner)

    conv_in = jnp.concatenate([cache["conv"], x[:, None, :]], axis=1)  # (B,d_conv,d_inner)
    x = jnp.einsum("bcd,cd->bd", conv_in, params["conv_w"]) + params["conv_b"]
    x = jax.nn.silu(x)
    new_conv = conv_in[:, 1:]

    proj = x @ params["x_proj"]
    dt, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"] + params["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[..., None] * A)  # (B,d_inner,d_state)
    dBx = (dt * x.astype(jnp.float32))[..., None] * Bmat.astype(jnp.float32)[:, None, :]
    h = dA * cache["ssm"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cmat.astype(jnp.float32)) + params["D"] * x.astype(jnp.float32)
    y = y.astype(u.dtype) * jax.nn.silu(z)
    return (y @ params["out_proj"])[:, None, :], {"conv": new_conv, "ssm": h}
