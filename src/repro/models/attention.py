"""Attention mixers: GQA (optionally sliding-window / qk-norm / biased) and
DeepSeek-style MLA (multi-head latent attention with a compressed KV cache).

Two entry points per mixer:
  * ``*_train``  — full-sequence causal attention (training / prefill).
  * ``*_decode`` — one new token against a pre-allocated cache (serving).

Caches:
  GQA full   : k/v of shape (B, Hkv, S_max, dh), absolute-position RoPE.
  GQA window : ring buffer of shape (B, Hkv, W, dh) — O(W) memory, enables
               the 500k-token decode shape for windowed configs.
  MLA        : compressed latent (B, S_max, kv_lora) + shared roped key
               (B, S_max, dr) — 64x smaller than a materialised KV cache; the
               decode path uses the "absorbed" formulation so per-step cost is
               linear in S with no per-head K/V expansion.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.layers import apply_rope, dense_init, rms_norm_headwise

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------


def init_attention(key, d_model: int, cfg: AttentionConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    if cfg.kind == "mla":
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        H = cfg.num_heads
        p = {
            "kv_a": dense_init(ks[0], (d_model, cfg.kv_lora_rank + dr), dtype),
            "kv_b": dense_init(ks[1], (cfg.kv_lora_rank, H * (dn + dv)), dtype),
            "w_o": dense_init(ks[2], (H * dv, d_model), dtype),
            "kv_a_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        }
        if cfg.q_lora_rank > 0:
            p["q_a"] = dense_init(ks[3], (d_model, cfg.q_lora_rank), dtype)
            p["q_b"] = dense_init(ks[4], (cfg.q_lora_rank, H * (dn + dr)), dtype)
            p["q_a_norm"] = jnp.ones((cfg.q_lora_rank,), dtype)
        else:
            p["w_q"] = dense_init(ks[3], (d_model, H * (dn + dr)), dtype)
        return p

    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "w_q": dense_init(ks[0], (d_model, H * dh), dtype),
        "w_k": dense_init(ks[1], (d_model, Hkv * dh), dtype),
        "w_v": dense_init(ks[2], (d_model, Hkv * dh), dtype),
        "w_o": dense_init(ks[3], (H * dh, d_model), dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((H * dh,), dtype)
        p["b_k"] = jnp.zeros((Hkv * dh,), dtype)
        p["b_v"] = jnp.zeros((Hkv * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def _project_qkv(params: dict, x: jnp.ndarray, cfg: AttentionConfig):
    B, S, _ = x.shape
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if cfg.qkv_bias:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = q.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, Hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, Hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm_headwise(q, params["q_norm"])
        k = rms_norm_headwise(k, params["k_norm"])
    return q, k, v


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """q: (B,H,Sq,dh), k: (B,Hkv,Sk,dh) -> (B,H,Sq,Sk) with KV grouping."""
    B, H, Sq, dh = q.shape
    Hkv = k.shape[1]
    qg = q.reshape(B, Hkv, groups, Sq, dh)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k)
    return s.reshape(B, H, Sq, k.shape[2])


def _gqa_mix(w: jnp.ndarray, v: jnp.ndarray, groups: int) -> jnp.ndarray:
    B, H, Sq, Sk = w.shape
    Hkv = v.shape[1]
    wg = w.reshape(B, Hkv, groups, Sq, Sk)
    o = jnp.einsum("bkgqs,bksd->bkgqd", wg, v)
    return o.reshape(B, H, Sq, v.shape[3])


# Above this sequence length the full (S x S) score matrix is not
# materialised: queries stream in blocks (flash-attention memory behaviour,
# expressed in pure JAX with lax.scan + remat; the Pallas kernel in
# repro/kernels is the TPU-fused version of the same schedule).
CHUNKED_ATTN_THRESHOLD = 8192
QUERY_BLOCK = 2048


def _attn_flash(q, k, v, cfg: AttentionConfig) -> jnp.ndarray:
    """Fused Pallas path (fwd + custom-vjp bwd). The kernel is plain MHA over
    flattened (B*H) heads, so GQA KV heads are broadcast to full heads first
    — the repeat is O(S·dh) HBM, dwarfed by not materialising the (S×S)
    score matrix."""
    from repro.kernels.flash import flash_attention
    from repro.kernels.ops import default_interpret

    if cfg.kv_groups > 1:
        k = jnp.repeat(k, cfg.kv_groups, axis=1)
        v = jnp.repeat(v, cfg.kv_groups, axis=1)
    return flash_attention(
        q, k, v, causal=True, window=cfg.window, interpret=default_interpret()
    )


def _attn_dense(q, k, v, cfg: AttentionConfig, q_offset: int | jnp.ndarray, S_kv: int):
    """Causal (optionally windowed) attention for one query block."""
    Sq = q.shape[2]
    scores = _gqa_scores(q, k, cfg.kv_groups) / math.sqrt(cfg.head_dim)
    i = q_offset + jnp.arange(Sq)[:, None]
    j = jnp.arange(S_kv)[None, :]
    mask = j <= i
    if cfg.window is not None:
        mask &= j > i - cfg.window
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_mix(w, v, cfg.kv_groups)


def gqa_train(
    params: dict, x: jnp.ndarray, cfg: AttentionConfig,
    use_kernels: bool = False,
) -> jnp.ndarray:
    B, S, d_model = x.shape
    q, k, v = _project_qkv(params, x, cfg)
    positions = jnp.arange(S)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if use_kernels:
        o = _attn_flash(q, k, v, cfg)
    elif S <= CHUNKED_ATTN_THRESHOLD or S % QUERY_BLOCK != 0:
        o = _attn_dense(q, k, v, cfg, 0, S)
    else:
        nblk = S // QUERY_BLOCK
        qb = q.reshape(B, q.shape[1], nblk, QUERY_BLOCK, cfg.head_dim)
        qb = jnp.moveaxis(qb, 2, 0)  # (nblk, B, H, qblk, dh)

        def body(_, args):
            blk_q, offset = args
            out = _attn_dense(blk_q, k, v, cfg, offset, S)
            return None, out

        offsets = jnp.arange(nblk) * QUERY_BLOCK
        _, ob = jax.lax.scan(jax.checkpoint(body), None, (qb, offsets))
        o = jnp.moveaxis(ob, 0, 2).reshape(B, q.shape[1], S, cfg.head_dim)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return o @ params["w_o"]


def init_gqa_cache(batch: int, seq_len: int, cfg: AttentionConfig, dtype) -> dict:
    size = cfg.window if cfg.window is not None else seq_len
    shape = (batch, cfg.num_kv_heads, size, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_decode(
    params: dict, x: jnp.ndarray, cache: dict, pos: jnp.ndarray, cfg: AttentionConfig
) -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, d_model); pos: scalar int32 — index of the new token."""
    B = x.shape[0]
    q, k, v = _project_qkv(params, x, cfg)  # (B,H,1,dh)/(B,Hkv,1,dh)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k = apply_rope(k, pos[None], cfg.rope_theta)

    size = cache["k"].shape[2]
    slot = pos % size if cfg.window is not None else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=2)

    scores = _gqa_scores(q, k_cache, cfg.kv_groups) / math.sqrt(cfg.head_dim)
    idx = jnp.arange(size)
    if cfg.window is not None:
        # slots hold tokens (pos - size, pos]; invalid until written
        age = (slot - idx) % size
        valid = age <= jnp.minimum(pos, size - 1)
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, :], scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_mix(w, v_cache, cfg.kv_groups)
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, -1)
    return o @ params["w_o"], {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


def _mla_queries(params: dict, x: jnp.ndarray, cfg: AttentionConfig):
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if "q_a" in params:
        cq = x @ params["q_a"]
        cq = rms_norm_headwise(cq, params["q_a_norm"])
        q = cq @ params["q_b"]
    else:
        q = x @ params["w_q"]
    q = q.reshape(B, S, H, dn + dr).transpose(0, 2, 1, 3)
    return q[..., :dn], q[..., dn:]  # nope, rope


def _mla_latent(params: dict, x: jnp.ndarray, cfg: AttentionConfig):
    ckv = x @ params["kv_a"]
    latent, k_rope = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank :]
    latent = rms_norm_headwise(latent, params["kv_a_norm"])
    return latent, k_rope  # (B,S,r), (B,S,dr)


def mla_train(params: dict, x: jnp.ndarray, cfg: AttentionConfig) -> jnp.ndarray:
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = jnp.arange(S)

    q_nope, q_rope = _mla_queries(params, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    latent, k_rope = _mla_latent(params, x, cfg)
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)  # (B,1,S,dr)

    kv = (latent @ params["kv_b"]).reshape(B, S, H, dn + dv).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :dn], kv[..., dn:]

    scale = 1.0 / math.sqrt(dn + dr)

    def block(q_n, q_r, offset):
        Sq = q_n.shape[2]
        scores = (
            jnp.einsum("bhqd,bhsd->bhqs", q_n, k_nope)
            + jnp.einsum("bhqd,bzsd->bhqs", q_r, k_rope)
        ) * scale
        i = offset + jnp.arange(Sq)[:, None]
        j = jnp.arange(S)[None, :]
        scores = jnp.where(j <= i, scores.astype(jnp.float32), NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        return jnp.einsum("bhqs,bhsd->bhqd", w, v)

    if S <= CHUNKED_ATTN_THRESHOLD or S % QUERY_BLOCK != 0:
        o = block(q_nope, q_rope, 0)
    else:
        nblk = S // QUERY_BLOCK
        qn = jnp.moveaxis(q_nope.reshape(B, H, nblk, QUERY_BLOCK, dn), 2, 0)
        qr = jnp.moveaxis(q_rope.reshape(B, H, nblk, QUERY_BLOCK, dr), 2, 0)

        def body(_, args):
            bq_n, bq_r, offset = args
            return None, block(bq_n, bq_r, offset)

        offsets = jnp.arange(nblk) * QUERY_BLOCK
        _, ob = jax.lax.scan(jax.checkpoint(body), None, (qn, qr, offsets))
        o = jnp.moveaxis(ob, 0, 2).reshape(B, H, S, dv)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    return o @ params["w_o"]


def init_mla_cache(batch: int, seq_len: int, cfg: AttentionConfig, dtype) -> dict:
    return {
        "latent": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(
    params: dict, x: jnp.ndarray, cache: dict, pos: jnp.ndarray, cfg: AttentionConfig
) -> Tuple[jnp.ndarray, dict]:
    """Absorbed MLA decode: attention runs in the compressed latent space."""
    B = x.shape[0]
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    q_nope, q_rope = _mla_queries(params, x, cfg)  # (B,H,1,dn/dr)
    q_rope = apply_rope(q_rope, pos[None], cfg.rope_theta)
    latent_t, k_rope_t = _mla_latent(params, x, cfg)  # (B,1,r), (B,1,dr)
    k_rope_t = apply_rope(k_rope_t, pos[None], cfg.rope_theta)

    latent = jax.lax.dynamic_update_slice_in_dim(cache["latent"], latent_t, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope_t, pos, axis=1)

    kv_b = params["kv_b"].reshape(r, H, dn + dv)
    w_uk, w_uv = kv_b[..., :dn], kv_b[..., dn:]  # (r,H,dn), (r,H,dv)
    # absorb the key up-projection into the query
    q_abs = jnp.einsum("bhqd,rhd->bhqr", q_nope, w_uk)  # (B,H,1,r)

    scale = 1.0 / math.sqrt(dn + dr)
    scores = (
        jnp.einsum("bhqr,bsr->bhqs", q_abs, latent)
        + jnp.einsum("bhqd,bsd->bhqs", q_rope, k_rope)
    ) * scale
    S = latent.shape[1]
    valid = jnp.arange(S) <= pos
    scores = jnp.where(valid[None, None, None, :], scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bhqr", w, latent)  # (B,H,1,r)
    o = jnp.einsum("bhqr,rhd->bhqd", o_lat, w_uv)  # (B,H,1,dv)
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, H * dv)
    return o @ params["w_o"], {"latent": latent, "k_rope": k_rope}
