"""xLSTM mixers: mLSTM (matrix memory, parallelisable) and sLSTM (scalar
memory with true recurrence) — arXiv:2405.04517.

mLSTM training uses the stabilised parallel (quadratic) formulation; decoding
uses the O(1) recurrent form with a per-head matrix memory C in (dh x dh).
sLSTM has a genuine sequential recurrence (block-diagonal recurrent weights),
so training scans over time with ``jax.lax.scan``.

Both carry a log-space stabiliser m to keep the exponential gating bounded,
matching the reference implementation.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_dims(d_model: int, cfg: SSMConfig) -> Tuple[int, int]:
    d_inner = int(cfg.proj_factor * d_model)
    dh = d_inner // cfg.num_heads
    return d_inner, dh


def init_mlstm(key, d_model: int, cfg: SSMConfig, dtype) -> dict:
    d_inner, dh = _mlstm_dims(d_model, cfg)
    ks = jax.random.split(key, 8)
    H = cfg.num_heads
    return {
        "up_proj": dense_init(ks[0], (d_model, 2 * d_inner), dtype),
        "w_q": dense_init(ks[1], (d_inner, d_inner), dtype),
        "w_k": dense_init(ks[2], (d_inner, d_inner), dtype),
        "w_v": dense_init(ks[3], (d_inner, d_inner), dtype),
        "w_i": dense_init(ks[4], (d_inner, H), dtype),
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_f": dense_init(ks[5], (d_inner, H), dtype),
        # positive forget-gate bias => long memory at init
        "b_f": jnp.linspace(3.0, 6.0, H).astype(jnp.float32),
        "skip_scale": jnp.ones((d_inner,), dtype),
        "down_proj": dense_init(ks[6], (d_inner, d_model), dtype),
    }


def _mlstm_qkv_gates(params: dict, x: jnp.ndarray, cfg: SSMConfig):
    """x: (B,S,d_inner) -> q,k,v (B,H,S,dh), log_i/log_f (B,H,S)."""
    B, S, d_inner = x.shape
    H = cfg.num_heads
    dh = d_inner // H

    def heads(y):
        return y.reshape(B, S, H, dh).transpose(0, 2, 1, 3)

    q = heads(x @ params["w_q"])
    k = heads(x @ params["w_k"]) / math.sqrt(dh)
    v = heads(x @ params["w_v"])
    log_i = (x @ params["w_i"] + params["b_i"]).astype(jnp.float32).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(
        (x @ params["w_f"] + params["b_f"]).astype(jnp.float32)
    ).transpose(0, 2, 1)
    return q, k, v, log_i, log_f


MLSTM_QUERY_BLOCK = 1024
MLSTM_CHUNK_THRESHOLD = 8192


def _mlstm_parallel_block(q, k, v, log_i, F, offset, S):
    """One query block of the stabilised parallel mLSTM form.

    q: (B,H,Sq,dh); k,v: (B,H,S,dh); log_i,F: (B,H,S); offset: block start.
    D[i,j] = F_i - F_j + log_i_j for j <= i.
    """
    Sq = q.shape[2]
    Fq = jax.lax.dynamic_slice_in_dim(F, offset, Sq, axis=2)
    D = Fq[..., :, None] - F[..., None, :] + log_i[..., None, :]
    i = offset + jnp.arange(Sq)[:, None]
    j = jnp.arange(S)[None, :]
    D = jnp.where(j <= i, D, NEG_INF)
    m = jnp.max(D, axis=-1)  # (B,H,Sq) row stabiliser
    Dstab = jnp.exp(D - m[..., None])
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    Sw = scores * Dstab
    n = jnp.maximum(jnp.abs(jnp.sum(Sw, axis=-1)), jnp.exp(-m))
    return jnp.einsum("bhqk,bhkd->bhqd", Sw / n[..., None], v.astype(jnp.float32))


def mlstm_train(params: dict, u: jnp.ndarray, cfg: SSMConfig) -> jnp.ndarray:
    B, S, d_model = u.shape
    xz = u @ params["up_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    q, k, v, log_i, log_f = _mlstm_qkv_gates(params, x, cfg)
    F = jnp.cumsum(log_f, axis=-1)  # (B,H,S): sum_{t<=i} log f_t

    if S <= MLSTM_CHUNK_THRESHOLD or S % MLSTM_QUERY_BLOCK != 0:
        h = _mlstm_parallel_block(q, k, v, log_i, F, 0, S)
    else:
        nblk = S // MLSTM_QUERY_BLOCK
        dh = q.shape[-1]
        qb = jnp.moveaxis(q.reshape(B, q.shape[1], nblk, MLSTM_QUERY_BLOCK, dh), 2, 0)

        def body(_, args):
            blk_q, offset = args
            return None, _mlstm_parallel_block(blk_q, k, v, log_i, F, offset, S)

        offsets = jnp.arange(nblk) * MLSTM_QUERY_BLOCK
        _, hb = jax.lax.scan(jax.checkpoint(body), None, (qb, offsets))
        h = jnp.moveaxis(hb, 0, 2).reshape(B, q.shape[1], S, dh)

    h = h.transpose(0, 2, 1, 3).reshape(B, S, -1).astype(u.dtype)
    h = h + params["skip_scale"] * x  # learnable skip, keeps signal at init
    y = h * jax.nn.silu(z)
    return y @ params["down_proj"]


def init_mlstm_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> dict:
    d_inner, dh = _mlstm_dims(d_model, cfg)
    H = cfg.num_heads
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(
    params: dict, u: jnp.ndarray, cache: dict, cfg: SSMConfig
) -> Tuple[jnp.ndarray, dict]:
    B, _, d_model = u.shape
    xz = u @ params["up_proj"]
    x, z = jnp.split(xz, 2, axis=-1)  # (B,1,d_inner)
    q, k, v, log_i, log_f = _mlstm_qkv_gates(params, x, cfg)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]  # (B,H,dh)
    log_i, log_f = log_i[..., 0], log_f[..., 0]  # (B,H)

    m_new = jnp.maximum(log_f + cache["m"], log_i)
    f_s = jnp.exp(log_f + cache["m"] - m_new)[..., None]
    i_s = jnp.exp(log_i - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = f_s[..., None] * cache["C"] + i_s[..., None] * kf[..., :, None] * vf[..., None, :]
    n = f_s * cache["n"] + i_s * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, -1).astype(u.dtype)
    h = h + params["skip_scale"] * x
    y = h * jax.nn.silu(z)
    return y @ params["down_proj"], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, d_model: int, cfg: SSMConfig, dtype) -> dict:
    H = cfg.num_heads
    dh = d_model // H
    ks = jax.random.split(key, 4)
    return {
        # input weights for the 4 gates (i, f, z, o) stacked on the last axis
        "w_x": dense_init(ks[0], (d_model, 4 * d_model), dtype),
        # block-diagonal recurrent weights: per head (dh, 4*dh)
        "w_r": dense_init(ks[1], (H, dh, 4 * dh), dtype, 1.0 / math.sqrt(dh)),
        "bias": jnp.concatenate(
            [jnp.zeros((d_model,)), jnp.linspace(3.0, 6.0, d_model), jnp.zeros((2 * d_model,))]
        ).astype(jnp.float32),
        "out_norm": jnp.ones((d_model,), dtype),
        "ff_up": dense_init(ks[2], (d_model, int(1.3 * d_model)), dtype),
        "ff_down": dense_init(ks[3], (int(1.3 * d_model), d_model), dtype),
    }


def _slstm_cell(params: dict, cfg: SSMConfig, x_t: jnp.ndarray, state: dict):
    """One sLSTM time step. x_t: (B, d_model)."""
    B, d_model = x_t.shape
    H = cfg.num_heads
    dh = d_model // H
    h_prev = state["h"]  # (B, d_model)
    hH = h_prev.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hH, params["w_r"]).reshape(B, 4 * d_model)
    pre = (x_t @ params["w_x"] + rec).astype(jnp.float32) + params["bias"]
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)

    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    c = f_s * state["c"] + i_s * jnp.tanh(z_pre)
    n = jnp.maximum(f_s * state["n"] + i_s, 1e-6)
    h = jax.nn.sigmoid(o_pre) * (c / n)
    new_state = {"h": h.astype(x_t.dtype), "c": c, "n": n, "m": m_new}
    return new_state, h.astype(x_t.dtype)


def init_slstm_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> dict:
    return {
        "h": jnp.zeros((batch, d_model), dtype),
        "c": jnp.zeros((batch, d_model), jnp.float32),
        "n": jnp.full((batch, d_model), 1e-6, jnp.float32),
        "m": jnp.full((batch, d_model), -1e30, jnp.float32),
    }


def slstm_train(params: dict, u: jnp.ndarray, cfg: SSMConfig) -> jnp.ndarray:
    B, S, d_model = u.shape
    state0 = init_slstm_cache(B, d_model, cfg, u.dtype)

    def step(state, x_t):
        return _slstm_cell(params, cfg, x_t, state)

    _, hs = jax.lax.scan(step, state0, u.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)  # (B,S,d)
    h = h * params["out_norm"]
    y = h + jax.nn.gelu(h @ params["ff_up"]) @ params["ff_down"]
    return y


def slstm_decode(
    params: dict, u: jnp.ndarray, cache: dict, cfg: SSMConfig
) -> Tuple[jnp.ndarray, dict]:
    new_state, h = _slstm_cell(params, cfg, u[:, 0], cache)
    h = h * params["out_norm"]
    y = h + jax.nn.gelu(h @ params["ff_up"]) @ params["ff_down"]
    return y[:, None, :], new_state
