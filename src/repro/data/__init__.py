from repro.data.synthetic import (
    SyntheticLM,
    batches,
    eval_batches,
    host_assembled_batches,
    process_local_batches,
    sharded_batches,
)

__all__ = [
    "SyntheticLM",
    "batches",
    "eval_batches",
    "host_assembled_batches",
    "process_local_batches",
    "sharded_batches",
]
