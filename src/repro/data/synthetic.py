"""Deterministic synthetic data pipeline.

The paper trains on OpenWebText; offline we generate a *learnable* synthetic
language so convergence curves are meaningful: tokens follow a Zipf unigram
prior modulated by a random order-1 Markov transition with a planted
low-rank structure. Losses therefore decrease substantially below the unigram
entropy only if the model actually learns the transitions — which is what the
convergence benchmarks need to separate optimizers.

Streams are seeded and reproducible; `sharded_batches` yields host-local
shards for the data-parallel axis.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import IGNORE_INDEX


class SyntheticLM:
    """Order-1 Markov token stream with planted low-rank structure.

    The transition matrix is LOW-RANK FACTORED and never materialised:
    P(next | cur) = softmax(log zipf + A[cur] @ B), with rows computed on the
    fly for the batch's current tokens — O(batch * V) per step instead of the
    O(V^2) dense table (18.9 GB at the paper's 50k vocab)."""

    def __init__(self, vocab: int, seed: int = 0, rank: int = 8, zipf_a: float = 1.2):
        rng = np.random.RandomState(seed)
        self.vocab = vocab
        base = 1.0 / np.arange(1, vocab + 1) ** zipf_a
        self.log_base = np.log(base / base.sum())
        self.A = (rng.randn(vocab, rank) * 2.0).astype(np.float32)
        self.B = (rng.randn(rank, vocab) * 2.0 / np.sqrt(rank)).astype(np.float32)
        self.rng = np.random.RandomState(seed + 1)

    def _rows(self, cur: np.ndarray) -> np.ndarray:
        """Transition rows P(. | cur) for a vector of current tokens."""
        logits = self.log_base[None, :] + self.A[cur] @ self.B
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        return p / p.sum(axis=1, keepdims=True)

    @property
    def table(self) -> np.ndarray:
        """Dense transition table — small vocabs only (tests/analysis).

        Returned as float32: the old f64 cast doubled the cache for no
        benefit and tripped the repo dtype policy. Sampling itself
        (`sample` -> `_rows` -> cumsum) is untouched, so fixed-seed token
        streams are bit-identical (regression-tested).
        """
        assert self.vocab <= 4096, "dense table only for small vocabularies"
        return self._rows(np.arange(self.vocab)).astype(np.float32)

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len + 1), np.int32)
        out[:, 0] = self.rng.randint(0, self.vocab, size=batch)
        for t in range(seq_len):
            cum = np.cumsum(self._rows(out[:, t]), axis=1)
            u = self.rng.rand(batch, 1)
            # clamped searchsorted draw: first index with cum > u. The old
            # `(u < cum).argmax(axis=1)` returned token 0 whenever float
            # rounding left u >= cum[-1] (all-False argmax), silently
            # spiking the head of the distribution; off that edge the two
            # formulas agree, so fixed-seed streams are unchanged.
            idx = (cum <= u).sum(axis=1)
            out[:, t + 1] = np.minimum(idx, self.vocab - 1)
        return out


def batches(
    cfg: ModelConfig,
    batch_size: int,
    seq_len: int,
    seed: int = 0,
    frontend_tokens: Optional[int] = None,
) -> Iterator[Dict[str, jnp.ndarray]]:
    """Infinite iterator of {tokens, labels[, frontend]} batches."""
    stream = SyntheticLM(cfg.vocab_size, seed)
    rng = np.random.RandomState(seed + 7)
    n_front = cfg.frontend_tokens if frontend_tokens is None else frontend_tokens
    while True:
        if cfg.num_codebooks > 1:
            toks = np.stack(
                [stream.sample(batch_size, seq_len) for _ in range(cfg.num_codebooks)],
                axis=-1,
            )  # (B, S+1, K)
            batch = {
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
            }
        else:
            toks = stream.sample(batch_size, seq_len)
            batch = {
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
            }
        if cfg.frontend is not None and n_front:
            dim = cfg.frontend_dim or cfg.d_model
            batch["frontend"] = jnp.asarray(
                rng.randn(batch_size, n_front, dim).astype(np.float32) * 0.02
            )
        yield batch


def sharded_batches(cfg, batch_size, seq_len, num_hosts, host_id, seed=0):
    """Host-local shard of the global batch (data-parallel loading).

    Every host walks the SAME seeded global stream and yields its contiguous
    row slice, so concatenating the host shards reproduces
    ``batches(cfg, batch_size, seq_len, seed)`` bit-for-bit — the invariant
    a multi-host data axis needs for runs to be reproducible across
    topologies. (The previous ``seed * num_hosts + host_id`` scheme gave
    hosts unrelated streams that did not partition any global batch.)

    Each host samples the full global batch and keeps 1/num_hosts of it: the
    Markov sampler draws one rng stream per batch, so row i's tokens depend
    on the draws for all rows — slicing after sampling is the only way to
    shard bit-exactly. For the synthetic generator that redundancy is pure
    CPU time; a real corpus loader would seek within one global shuffle
    order instead.
    """
    assert batch_size % num_hosts == 0, (
        f"global batch {batch_size} must divide over {num_hosts} hosts"
    )
    local = batch_size // num_hosts
    lo, hi = host_id * local, (host_id + 1) * local
    for batch in batches(cfg, batch_size, seq_len, seed=seed):
        yield {k: v[lo:hi] for k, v in batch.items()}


def host_assembled_batches(cfg, batch_size, seq_len, num_hosts, seed=0):
    """Global stream reassembled from per-host shard iterators.

    Single-process emulation of multi-host loading: drives one
    `sharded_batches` iterator per host and concatenates their slices, so
    the driver exercises the exact sharded loading path while feeding the
    engine the global batch a single process needs. Bit-identical to
    ``batches(cfg, batch_size, seq_len, seed)``.
    """
    its = [
        sharded_batches(cfg, batch_size, seq_len, num_hosts, h, seed=seed)
        for h in range(num_hosts)
    ]
    while True:
        shards = [next(it) for it in its]
        yield {
            k: jnp.concatenate([s[k] for s in shards], axis=0)
            for k in shards[0]
        }


def process_local_batches(
    cfg,
    batch_size,
    seq_len,
    num_microbatches,
    data_shards,
    shard_lo,
    shard_hi,
    seed=0,
):
    """Process-local slice of the global MICROBATCHED stream (the
    multi-controller loading path).

    The pipeline consumes the global batch as ``(B, S) -> (M, B//M, S)``
    with the microbatch rows sharded over the (pod-major) data axes. A
    process owning data shards ``[shard_lo, shard_hi)`` of ``data_shards``
    (`Topology.process_data_shards`) must therefore supply, for EVERY
    microbatch, its row-shard slice — rows that are interleaved, not
    contiguous, in the flat ``(B, S)`` stream. This iterator yields exactly
    that addressable portion, shaped ``(M, (hi-lo) * B//M//shards, S)``, for
    `jax.make_array_from_process_local_data`; stacking the per-shard slices
    over a partition of ``range(data_shards)`` reproduces the single-process
    global reshape bit-for-bit, so runs are reproducible across process
    counts (and elastic resumes keep consuming the identical stream).

    Like `sharded_batches`, each process samples the full global batch and
    keeps its slice (the Markov sampler's rng couples rows); a real corpus
    loader would seek to the interleaved offsets within one global shuffle
    order instead.
    """
    M = num_microbatches
    assert batch_size % M == 0, (
        f"global batch {batch_size} must divide into {M} microbatches"
    )
    mb = batch_size // M
    assert mb % data_shards == 0, (
        f"microbatch size {mb} must divide over {data_shards} data shards"
    )
    assert 0 <= shard_lo < shard_hi <= data_shards, (
        f"shard range [{shard_lo}, {shard_hi}) outside [0, {data_shards})"
    )
    w = mb // data_shards
    for batch in batches(cfg, batch_size, seq_len, seed=seed):
        out = {}
        for k, v in batch.items():
            v = np.asarray(v)
            rest = v.shape[1:]
            local = v.reshape(M, data_shards, w, *rest)[:, shard_lo:shard_hi]
            out[k] = local.reshape(M, (shard_hi - shard_lo) * w, *rest)
        yield out


def eval_batches(cfg, batch_size, seq_len, n, seed=10_000):
    it = batches(cfg, batch_size, seq_len, seed)
    return [next(it) for _ in range(n)]
