from repro.checkpoint.ckpt import (
    load_checkpoint,
    save_checkpoint,
    save_sharded_checkpoint,
    stage_shard_axes,
)

__all__ = [
    "load_checkpoint",
    "save_checkpoint",
    "save_sharded_checkpoint",
    "stage_shard_axes",
]
