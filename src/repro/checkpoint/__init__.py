from repro.checkpoint.ckpt import (
    ShardedSnapshot,
    load_checkpoint,
    save_checkpoint,
    save_sharded_checkpoint,
    snapshot_sharded,
    stage_shard_axes,
    write_sharded_checkpoint,
)

__all__ = [
    "ShardedSnapshot",
    "load_checkpoint",
    "save_checkpoint",
    "save_sharded_checkpoint",
    "snapshot_sharded",
    "stage_shard_axes",
    "write_sharded_checkpoint",
]
