"""Pytree checkpointing: .npz arrays + JSON manifest of the tree structure.

Handles arbitrary nesting of dicts / lists / tuples / None with jnp or numpy
leaves. Restores exact dtypes and shapes; round-trips optimizer states
(including the basis-rotation leaf list and delay-FIFO queues) and params.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _spec(tree: Any, prefix: str = "") -> Any:
    if tree is None:
        return {"__kind__": "none"}
    if isinstance(tree, dict):
        return {
            "__kind__": "dict",
            "keys": sorted(tree.keys()),
            "children": {k: _spec(tree[k]) for k in sorted(tree.keys())},
        }
    if isinstance(tree, (list, tuple)):
        return {
            "__kind__": "list" if isinstance(tree, list) else "tuple",
            "children": [_spec(x) for x in tree],
        }
    return {"__kind__": "leaf"}


def save_checkpoint(path: str, tree: Any, step: int = 0, meta: Dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves = jax.tree_util.tree_leaves(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    # Crash-safe save: the arrays go to a step-versioned file (written under a
    # temp name, then os.replace'd), and the manifest — swapped in LAST —
    # names that file, so the manifest replace is the single atomic commit
    # point. A crash anywhere mid-save leaves the old manifest pointing at
    # the old arrays file, which is only garbage-collected after the new
    # manifest lands. (Temp name ends in .npz: np.savez appends the
    # extension to anything else.)
    arrays_name = f"arrays-{step:08d}.npz"
    arrays_tmp = os.path.join(path, ".arrays.tmp.npz")
    manifest_tmp = os.path.join(path, ".manifest.tmp.json")
    np.savez(arrays_tmp, **arrays)
    manifest = {"spec": _spec(tree), "num_leaves": len(leaves), "step": step,
                "arrays_file": arrays_name, "meta": meta or {}}
    with open(manifest_tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(arrays_tmp, os.path.join(path, arrays_name))
    os.replace(manifest_tmp, os.path.join(path, "manifest.json"))
    for name in os.listdir(path):  # drop superseded array files
        if name != arrays_name and (
            name == "arrays.npz"
            or (name.startswith("arrays-") and name.endswith(".npz"))
        ):
            try:
                os.remove(os.path.join(path, name))
            except OSError:  # pragma: no cover — another writer raced us
                pass


def _rebuild(spec: Any, leaves: list, pos: list) -> Any:
    kind = spec["__kind__"]
    if kind == "none":
        return None
    if kind == "leaf":
        x = leaves[pos[0]]
        pos[0] += 1
        return jnp.asarray(x)
    if kind == "dict":
        return {k: _rebuild(spec["children"][k], leaves, pos) for k in spec["keys"]}
    children = [_rebuild(c, leaves, pos) for c in spec["children"]]
    return children if kind == "list" else tuple(children)


def load_checkpoint(path: str) -> Tuple[Any, int, Dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    # pre-atomic-save checkpoints used a fixed "arrays.npz" name
    data = np.load(os.path.join(path, manifest.get("arrays_file", "arrays.npz")))
    leaves = [data[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    tree = _rebuild(manifest["spec"], leaves, [0])
    return tree, manifest["step"], manifest.get("meta", {})
