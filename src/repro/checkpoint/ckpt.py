"""Pytree checkpointing: .npz arrays + JSON manifest of the tree structure.

Handles arbitrary nesting of dicts / lists / tuples / None with jnp or numpy
leaves. Restores exact dtypes and shapes; round-trips optimizer states
(including the basis-rotation leaf list and delay-FIFO queues) and params.

Two on-disk formats behind the same `load_checkpoint`:

* **gathered** (`save_checkpoint`): one arrays file holding every leaf —
  the single-host format.
* **sharded** (`save_sharded_checkpoint`): one arrays file per pipeline-stage
  shard. Each stage-sharded leaf (detected from its `NamedSharding`, or
  given explicitly) is sliced along its stage axis across the shard files;
  replicated leaves live in shard 0 only. The manifest records per-leaf
  shard axes, so loading reassembles the global tree regardless of the
  topology it is reloaded under — save on (pod=1, data=2), resume on
  (pod=2, data=1).

Both formats share the atomic-save discipline: every arrays file is written
to a temp name and `os.replace`d into a step-versioned name, and the
manifest — swapped in LAST — is the single commit point. A crash anywhere
mid-save leaves the previous manifest pointing at the previous (complete)
file set.

The sharded save is split into two halves so checkpoints can be written off
the training thread: `snapshot_sharded` (device -> host numpy slices, needs
the LIVE leaves' sharding metadata, runs on the loop thread) and
`write_sharded_checkpoint` (file I/O + the 3-barrier commit, safe on a
background writer). `save_sharded_checkpoint` is their synchronous
composition.
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

STAGE_AXIS_NAME = "stage"

_SHARD_FILE_RE = re.compile(
    r"^arrays-\d{8}-shard(\d{5})-of-\d{5}(?:-g\d+)?\.npz$"
)
_SHARD_TMP_RE = re.compile(r"^\.arrays\.shard(\d{5})\.tmp\.npz$")


def _spec(tree: Any, prefix: str = "") -> Any:
    if tree is None:
        return {"__kind__": "none"}
    if isinstance(tree, dict):
        return {
            "__kind__": "dict",
            "keys": sorted(tree.keys()),
            "children": {k: _spec(tree[k]) for k in sorted(tree.keys())},
        }
    if isinstance(tree, (list, tuple)):
        return {
            "__kind__": "list" if isinstance(tree, list) else "tuple",
            "children": [_spec(x) for x in tree],
        }
    return {"__kind__": "leaf"}


def save_checkpoint(path: str, tree: Any, step: int = 0, meta: Dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves = jax.tree_util.tree_leaves(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    # Crash-safe save: the arrays go to a step-versioned file (written under a
    # temp name, then os.replace'd), and the manifest — swapped in LAST —
    # names that file, so the manifest replace is the single atomic commit
    # point. A crash anywhere mid-save leaves the old manifest pointing at
    # the old arrays file, which is only garbage-collected after the new
    # manifest lands. (Temp name ends in .npz: np.savez appends the
    # extension to anything else.)
    arrays_name = f"arrays-{step:08d}.npz"
    arrays_tmp = os.path.join(path, ".arrays.tmp.npz")
    manifest_tmp = os.path.join(path, ".manifest.tmp.json")
    np.savez(arrays_tmp, **arrays)
    manifest = {"spec": _spec(tree), "num_leaves": len(leaves), "step": step,
                "arrays_file": arrays_name, "meta": meta or {}}
    with open(manifest_tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(arrays_tmp, os.path.join(path, arrays_name))
    os.replace(manifest_tmp, os.path.join(path, "manifest.json"))
    _gc_array_files(path, keep={arrays_name})


def _gc_array_files(
    path: str, keep: set, owned_shards: Optional[Set[int]] = None
) -> None:
    """Drop array files superseded by a just-committed manifest (both the
    gathered and the sharded naming schemes), plus temp files stranded by an
    interrupted earlier save.

    ``owned_shards`` restricts a multi-controller process to collecting only
    the shard files (and shard temp files) it owns — processes never race on
    each other's files; the manifest-writing process is the one that may
    additionally collect gathered-format leftovers (callers pass
    ``owned_shards=None`` for the single-controller everything-is-mine case).
    """
    for name in os.listdir(path):
        if name in keep:
            continue
        m = _SHARD_FILE_RE.match(name) or _SHARD_TMP_RE.match(name)
        if m is not None:
            if owned_shards is not None and int(m.group(1)) not in owned_shards:
                continue
        else:
            gathered_stale = name == "arrays.npz" or (
                name.startswith("arrays-") and name.endswith(".npz")
            ) or (name.startswith(".arrays") and name.endswith(".tmp.npz"))
            if not gathered_stale or owned_shards is not None and 0 not in owned_shards:
                continue
        try:
            os.remove(os.path.join(path, name))
        except OSError:  # pragma: no cover — another writer raced us
            pass


# ---------------------------------------------------------------------------
# Stage-sharded format
# ---------------------------------------------------------------------------


def stage_shard_axes(
    tree: Any, axis_name: str = STAGE_AXIS_NAME, num_shards: int = 0
) -> List[Optional[int]]:
    """Per-leaf shard axis (ordered like ``tree_flatten``), read off each
    leaf's `NamedSharding`: the first array dimension whose partition spec
    mentions ``axis_name``, or None for leaves the runtime replicates.

    Leaves whose detected axis is not divisible by ``num_shards`` degrade to
    None (stored replicated) — the shard layout is a storage optimisation,
    never a correctness requirement.
    """
    axes: List[Optional[int]] = []
    for leaf in jax.tree_util.tree_leaves(tree):
        ax = None
        spec = getattr(getattr(leaf, "sharding", None), "spec", None)
        if spec is not None:
            for i, part in enumerate(spec):
                names = part if isinstance(part, tuple) else (part,)
                if axis_name in names:
                    ax = i
                    break
        if ax is not None and num_shards > 0 and (
            ax >= leaf.ndim or leaf.shape[ax] % num_shards != 0
        ):
            ax = None
        axes.append(ax)
    return axes


def _shard_file_name(step: int, shard: int, num_shards: int, gen: int = 0) -> str:
    suffix = f"-g{gen}" if gen else ""
    return f"arrays-{step:08d}-shard{shard:05d}-of-{num_shards:05d}{suffix}.npz"


def _is_partially_addressable(leaf: Any) -> bool:
    """True for a multi-controller global array this process only holds a
    slab of (numpy arrays and single-controller jax.Arrays are fully
    addressable and slice directly)."""
    return isinstance(leaf, jax.Array) and not leaf.is_fully_addressable


def _np_replicated(leaf: Any) -> np.ndarray:
    """Full value of a leaf with no shard axis."""
    if _is_partially_addressable(leaf):
        if not leaf.is_fully_replicated:
            raise ValueError(
                f"leaf of shape {leaf.shape} has no recorded shard axis but "
                f"is not replicated (sharding {leaf.sharding}); it cannot be "
                f"checkpointed from one process"
            )
        return np.asarray(leaf.addressable_shards[0].data)
    return np.asarray(leaf)


def _np_shard_slice(leaf: Any, ax: int, s: int, num_shards: int) -> np.ndarray:
    """Slice ``s`` of ``num_shards`` along ``ax`` — assembled from LOCAL
    addressable shards for multi-controller global arrays (slicing the
    global array would lower a cross-process program; checkpointing must
    never communicate)."""
    width = leaf.shape[ax] // num_shards
    lo, hi = s * width, (s + 1) * width
    if not _is_partially_addressable(leaf):
        sl = [slice(None)] * leaf.ndim
        sl[ax] = slice(lo, hi)
        # slicing the (fully addressable) jax.Array pulls only this piece
        return np.asarray(leaf[tuple(sl)])
    pieces: Dict[Tuple[int, int], Any] = {}
    for sh in leaf.addressable_shards:
        idx = sh.index
        a = idx[ax].start or 0
        b = leaf.shape[ax] if idx[ax].stop is None else idx[ax].stop
        if a >= hi or b <= lo:
            continue
        if a < lo or b > hi:
            raise ValueError(
                f"device shard [{a}:{b}] straddles checkpoint shard "
                f"[{lo}:{hi}] of axis {ax} (shape {leaf.shape}); the live "
                f"sharding must tile the {num_shards} checkpoint shards"
            )
        for d, ix in enumerate(idx):
            full = (0, leaf.shape[d])
            got = (ix.start or 0, leaf.shape[d] if ix.stop is None else ix.stop)
            if d != ax and got != full:
                raise ValueError(
                    f"leaf sharded along axis {d} besides the shard axis "
                    f"{ax} (sharding {leaf.sharding}); not a stage-sharded "
                    f"checkpoint layout"
                )
        pieces.setdefault((a, b), sh.data)  # replicas across pods dedupe
    cursor, ordered = lo, []
    for (a, b), data in sorted(pieces.items()):
        if a != cursor:
            break
        ordered.append(np.asarray(data))
        cursor = b
    if cursor != hi:
        raise ValueError(
            f"process does not address checkpoint shard {s} of axis {ax} "
            f"(covered up to {cursor} of [{lo}:{hi}]); shard ownership and "
            f"the live sharding disagree"
        )
    return ordered[0] if len(ordered) == 1 else np.concatenate(ordered, axis=ax)


@dataclass
class ShardedSnapshot:
    """Host-side image of a sharded checkpoint, decoupled from the live
    device arrays.

    `snapshot_sharded` builds it EAGERLY on the training thread — slicing
    each owned shard to host numpy while the leaves' `NamedSharding`
    metadata is still live (a donated train step deletes/reuses the source
    buffers as soon as the next step is dispatched, and numpy copies are
    the only thing a background writer may touch). `write_sharded_checkpoint`
    then does the file I/O — safe to run on a writer thread after the loop
    has moved on.
    """

    spec: Any
    num_leaves: int
    num_shards: int
    shard_axes: List[Optional[int]]
    # shard id -> {"leaf_i": host array}; only the owned shards are present
    arrays: Dict[int, Dict[str, np.ndarray]]
    owned: Set[int]


def snapshot_sharded(
    tree: Any,
    num_shards: int,
    shard_axes: Optional[Sequence[Optional[int]]] = None,
    axis_name: str = STAGE_AXIS_NAME,
    owned_shards: Optional[Sequence[int]] = None,
) -> ShardedSnapshot:
    """Slice `tree` into a host-memory `ShardedSnapshot` (no file I/O).

    Shard s holds, for every leaf with a shard axis, slice s of
    ``num_shards`` along that axis (stage-stacked params/moments slice on
    axis 0, the delay-FIFO queues on their stage axis); shard 0 additionally
    holds the replicated leaves (shared params, scalar counters).
    ``shard_axes`` overrides the per-leaf axis detection (ints or None,
    ``tree_flatten`` order); by default axes are read from each leaf's
    `NamedSharding` via `stage_shard_axes`. ``owned_shards`` restricts a
    multi-controller process to slicing only its own shards (from locally
    addressable device shards — no cross-process traffic).
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    leaves = jax.tree_util.tree_leaves(tree)
    if shard_axes is None:
        shard_axes = stage_shard_axes(tree, axis_name, num_shards)
    shard_axes = list(shard_axes)
    assert len(shard_axes) == len(leaves), "shard_axes must match leaf count"
    for i, (leaf, ax) in enumerate(zip(leaves, shard_axes)):
        if ax is not None and (
            ax >= leaf.ndim or leaf.shape[ax] % num_shards != 0
        ):
            raise ValueError(
                f"leaf {i}: axis {ax} of shape {leaf.shape} is not divisible "
                f"into {num_shards} shards"
            )
    owned = set(range(num_shards)) if owned_shards is None else set(owned_shards)
    arrays: Dict[int, Dict[str, np.ndarray]] = {}
    for s in sorted(owned):
        shard = {}
        for i, (leaf, ax) in enumerate(zip(leaves, shard_axes)):
            if ax is None:
                if s == 0:
                    shard[f"leaf_{i}"] = _np_replicated(leaf)
            else:
                shard[f"leaf_{i}"] = _np_shard_slice(leaf, ax, s, num_shards)
        arrays[s] = shard
    return ShardedSnapshot(
        spec=_spec(tree), num_leaves=len(leaves), num_shards=num_shards,
        shard_axes=shard_axes, arrays=arrays, owned=owned,
    )


def write_sharded_checkpoint(
    path: str,
    snapshot: ShardedSnapshot,
    step: int = 0,
    meta: Dict | None = None,
    write_manifest: bool = True,
    barrier: Optional[Callable[[str], None]] = None,
) -> None:
    """Commit a `ShardedSnapshot` to disk under the 3-barrier atomic
    protocol. Pure host-side file I/O — safe on a background writer thread.

    The manifest is written last and names the full file set, so
    interrupted saves leave the previous checkpoint loadable
    (`load_checkpoint` serves both this and the gathered format).

    **Multi-controller contract.** Every process calls this at the same
    step with its own snapshot (owned shards partition
    ``range(num_shards)`` across processes — `Topology.shard_owners`),
    exactly one process passes ``write_manifest=True``, and ``barrier`` is
    the cross-process rendezvous (`repro.launch.distributed.barrier`). Each
    process writes ONLY its own shard files. Three barriers order the
    phases: (1) after the generation scan, so every process names the same
    file set before anyone writes; (2) after the shard writes, so the
    manifest — the single commit point — never names a file that isn't
    fully on disk; (3) after the manifest commit, so no process
    garbage-collects files the manifest still needs. The defaults (all
    shards owned, no barrier) are the unchanged single-controller path.
    Asynchronous writers must keep the submission order of checkpoints and
    run ONE writer per process, so the barrier sequence stays globally
    ordered (engine.loop's serial writer thread guarantees this).
    """
    os.makedirs(path, exist_ok=True)
    num_shards = snapshot.num_shards
    # never overwrite committed files in place: if this step was saved before
    # (re-run into an old dir, run_loop's final-step double save), pick fresh
    # names so a crash mid-save cannot leave the old manifest pointing at a
    # mixed old/new shard set; the superseded files are GC'd after the
    # manifest commit. Every process scans BEFORE anyone writes (barrier), so
    # all pick the same generation from the same directory state.
    gen = 0
    while any(
        os.path.exists(os.path.join(path, _shard_file_name(step, s, num_shards, gen)))
        for s in range(num_shards)
    ):
        gen += 1
    shard_files = [
        _shard_file_name(step, s, num_shards, gen) for s in range(num_shards)
    ]
    if barrier is not None:
        barrier(f"ckpt-{step}-g{gen}-named")
    for s in sorted(snapshot.owned):
        tmp = os.path.join(path, f".arrays.shard{s:05d}.tmp.npz")
        np.savez(tmp, **snapshot.arrays[s])
        os.replace(tmp, os.path.join(path, shard_files[s]))
    if barrier is not None:
        barrier(f"ckpt-{step}-g{gen}-shards")

    if write_manifest:
        manifest = {
            "format": "sharded",
            "spec": snapshot.spec,
            "num_leaves": snapshot.num_leaves,
            "num_shards": num_shards,
            "shard_axes": snapshot.shard_axes,
            "shard_files": shard_files,
            "step": step,
            "meta": meta or {},
        }
        manifest_tmp = os.path.join(path, ".manifest.tmp.json")
        with open(manifest_tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(manifest_tmp, os.path.join(path, "manifest.json"))
    if barrier is not None:
        barrier(f"ckpt-{step}-g{gen}-commit")
    all_owned = snapshot.owned == set(range(num_shards))
    _gc_array_files(
        path, keep=set(shard_files),
        owned_shards=None if all_owned else snapshot.owned,
    )


def save_sharded_checkpoint(
    path: str,
    tree: Any,
    num_shards: int,
    step: int = 0,
    meta: Dict | None = None,
    shard_axes: Optional[Sequence[Optional[int]]] = None,
    axis_name: str = STAGE_AXIS_NAME,
    owned_shards: Optional[Sequence[int]] = None,
    write_manifest: bool = True,
    barrier: Optional[Callable[[str], None]] = None,
) -> None:
    """Synchronous per-stage-shard checkpoint: `snapshot_sharded` (device ->
    host slices) immediately followed by `write_sharded_checkpoint` (atomic
    3-barrier commit) on the calling thread. The async path in engine.loop
    calls the two halves separately so only the snapshot blocks training.
    """
    snapshot = snapshot_sharded(
        tree, num_shards, shard_axes=shard_axes, axis_name=axis_name,
        owned_shards=owned_shards,
    )
    write_sharded_checkpoint(
        path, snapshot, step=step, meta=meta,
        write_manifest=write_manifest, barrier=barrier,
    )


def _load_sharded_leaves(path: str, manifest: Dict) -> list:
    shards = [np.load(os.path.join(path, f)) for f in manifest["shard_files"]]
    leaves = []
    for i in range(manifest["num_leaves"]):
        ax = manifest["shard_axes"][i]
        key = f"leaf_{i}"
        if ax is None:
            leaves.append(shards[0][key])
        else:
            leaves.append(
                np.concatenate([sh[key] for sh in shards], axis=int(ax))
            )
    return leaves


def _rebuild(spec: Any, leaves: list, pos: list) -> Any:
    kind = spec["__kind__"]
    if kind == "none":
        return None
    if kind == "leaf":
        x = leaves[pos[0]]
        pos[0] += 1
        return jnp.asarray(x)
    if kind == "dict":
        return {k: _rebuild(spec["children"][k], leaves, pos) for k in spec["keys"]}
    children = [_rebuild(c, leaves, pos) for c in spec["children"]]
    return children if kind == "list" else tuple(children)


def load_checkpoint(path: str) -> Tuple[Any, int, Dict]:
    """Load either format, returning the fully assembled (global) tree.

    Sharded checkpoints are reassembled by concatenating each leaf's shard
    slices along its recorded axis — the caller (engine / jit) re-shards the
    result onto whatever topology it is running, which may differ from the
    one that saved.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") == "sharded":
        leaves = _load_sharded_leaves(path, manifest)
    else:
        # pre-atomic-save checkpoints used a fixed "arrays.npz" name
        data = np.load(os.path.join(path, manifest.get("arrays_file", "arrays.npz")))
        leaves = [data[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    tree = _rebuild(manifest["spec"], leaves, [0])
    return tree, manifest["step"], manifest.get("meta", {})
