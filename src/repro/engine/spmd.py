"""Distributed pipeline backend: `shard_map` over a `stage` mesh axis.

TPU adaptation of PipeDream (DESIGN.md §3): activations move between
neighbouring stages with `jax.lax.ppermute` inside one jitted program. The
tick schedules live in `repro.engine.schedules` behind one interface —
``fill_drain`` (forward scan + autodiff backward, O(M) activation buffer) and
``1f1b`` (interleaved explicit forward/backward ticks, O(K) activation
stash). Both scan the tick body, so the traced program is O(1) in both
microbatches and stages — the jaxpr for M=64 is the same size as for M=4.

Staleness (the async part) is applied by composing the resulting gradient
with the per-stage delay FIFO (`repro.pipeline.delay.stage_delayed_optimizer`)
— deterministic PipeDream weight-stashing semantics on SPMD hardware. Stage k
applies the gradient computed tau_k = K-1-k steps ago; sharded over `stage`,
each device's FIFO slice holds exactly its own stage's stash (linear-in-depth
memory, paper Section 4.3).

The pipeline runtime targets homogeneous decoder stacks (the paper's models):
layers are split contiguously into K equal stages, each device along the
`stage` axis holds its stage's layer stack; embedding / final norm / LM head
are replicated and only contribute on the first/last stage.

`SpmdEngine` packages all of it — pipeline grads, per-stage delay, any
`build_optimizer` base — behind the `PipelineEngine` interface so the shared
loop (and `repro.launch.train --backend spmd`) drives it end to end.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import (
    PRECISION_POLICIES,
    ModelConfig,
    OptimizerConfig,
    PrecisionPolicy,
)
from repro.engine.base import EngineState, PipelineEngine
from repro.engine.schedules import make_fill_drain_loss, make_schedule_grad
from repro.launch.topology import Topology
from repro.pipeline.partition import FIRST_STAGE_SHARED, stage_context_for_stacked

# Backwards-compatible alias; the canonical list lives with the partition
# helpers (`repro.pipeline.partition.FIRST_STAGE_SHARED`).
_FIRST_STAGE_SHARED = FIRST_STAGE_SHARED


def stack_stage_params(params: Dict, cfg: ModelConfig, num_stages: int) -> Tuple[Dict, Dict]:
    """Split an unstacked model into (stage_stacked_blocks, shared).

    stage_stacked leaves: (K, layers_per_stage, ...); shared = embedding,
    positional embedding, final norm, LM head (replicated).
    """
    assert not cfg.scan_layers, "pipeline stacking starts from per-layer params"
    L = cfg.num_layers
    assert L % num_stages == 0, "layers must divide evenly across stages"
    per = L // num_stages
    blocks = params["blocks"]
    # stack layers within a stage, then stack stages
    stages = []
    for k in range(num_stages):
        layer_group = blocks[k * per : (k + 1) * per]
        stages.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layer_group))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *stages)
    shared = {k: v for k, v in params.items() if k != "blocks"}
    return stacked, shared


def unstack_stage_params(stacked: Dict, shared: Dict, cfg: ModelConfig) -> Dict:
    K = jax.tree.leaves(stacked)[0].shape[0]
    per = jax.tree.leaves(stacked)[0].shape[1]
    blocks = []
    for k in range(K):
        for l in range(per):
            blocks.append(jax.tree.map(lambda x: x[k, l], stacked))
    out = dict(shared)
    out["blocks"] = tuple(blocks)
    return out


def make_pipeline_loss(
    cfg: ModelConfig,
    mesh: Mesh,
    num_stages: int,
    num_microbatches: int,
    stage_axis: str = "stage",
    data_axis: str = "data",
):
    """Fill-drain loss_fn(stage_params, shared, batch) -> scalar.

    Only the fill-drain schedule has a standalone differentiable loss; the
    1F1B schedule builds its gradient explicitly — use ``make_pipeline_grad``
    with ``schedule="1f1b"`` for it.
    """
    return make_fill_drain_loss(
        cfg, mesh, num_stages, num_microbatches,
        stage_axis=stage_axis, data_axis=data_axis,
    )


def make_pipeline_grad(
    cfg, mesh, num_stages, num_microbatches, schedule: str = "fill_drain", **kw
):
    """grad_fn(stage_params, shared, batch) -> (loss, (g_stacked, g_shared))
    under the chosen tick schedule (``"fill_drain"`` or ``"1f1b"``)."""
    return make_schedule_grad(
        cfg, mesh, num_stages, num_microbatches, schedule=schedule, **kw
    )


# ---------------------------------------------------------------------------
# Delay specs for the stage-stacked parameter layout
# ---------------------------------------------------------------------------


def spmd_delay_specs(
    stacked: Any, shared: Any, num_stages: int
) -> List[Union[int, str]]:
    """Per-leaf delay spec for the (stacked, shared) tuple, ordered like
    ``jax.tree_util.tree_flatten((stacked, shared))``.

    Thin wrapper over `stage_context_for_stacked` — the partition module owns
    the stacked/shared delay rules; this re-export survives for callers of
    the pre-StageContext API.
    """
    return stage_context_for_stacked(stacked, shared, num_stages).delay_specs()


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class SpmdEngine(PipelineEngine):
    """Complete async SPMD train step: pipeline grads composed with the
    per-stage delay FIFO around any `build_optimizer` base.

    ``schedule`` picks the tick schedule: ``"fill_drain"`` (O(M) activation
    buffer per stage) or ``"1f1b"`` (O(K) stash). Both produce the same
    synchronous gradient to fp32 tolerance, so either composes unchanged with
    the delay FIFO. ``async_grads=False`` drops the delay wrapper — the
    synchronous-gradient reference used to cross-check the two backends
    against each other.

    The optimizer is built from a stacked-layout `StageContext`
    (`stage_context_for_stacked`), so every `build_optimizer` base runs
    natively on the ``(K, per, ...)`` leaves: stage-aware rotation
    frequencies vectorize over the leading stage axis, PipeDream-LR scales
    per stage slice, and delay compensation reads the per-stage stale weight
    snapshot the FIFO queues (``store_params``). ``use_kernels=True`` routes
    the basis-rotation matmuls and the fused Adam scale through the Pallas
    kernels (`repro.kernels.ops`), interpreted off-TPU.

    ``data_async=True, data_delay=D`` (D > 0) makes the DATA axis
    asynchronous too (bounded staleness): the step program computes
    per-replica local gradients with no ``(pod, data)`` collective on the
    critical path and applies the D-step-old deferred global reduction
    from an engine-level FIFO; a separate jitted reduce program (the only
    one containing the data all-reduce) folds the fresh local grads for
    consumption D steps later. Delay-aware optimizers see total staleness
    tau_k + D through the `StageContext`. ``data_delay=0`` construction-
    gates to the synchronous path, bit-identical to ``data_async=False``.

    ``topology`` places the engine on a `(pod, stage, data)` device layout
    (`repro.launch.topology.Topology`): the mesh comes from
    ``topology.make_mesh()`` and the gradient/loss data reduction spans
    every data axis — ``("pod", "data")`` on multi-pod shapes. ``mesh`` is
    still accepted for callers that pre-built one (its topology is recovered
    via `Topology.from_mesh`); with neither, the engine uses every visible
    device as a single-pod ``(stage, data)`` layout.
    """

    name = "spmd"

    def __init__(
        self,
        cfg: ModelConfig,
        ocfg: OptimizerConfig,
        num_stages: int,
        num_microbatches: int = 0,
        mesh: Optional[Mesh] = None,
        grad_clip: float = 1.0,
        async_grads: bool = True,
        schedule: str = "fill_drain",
        use_kernels: bool = False,
        topology: Optional[Topology] = None,
        precision: Union[str, PrecisionPolicy, None] = None,
        donate: Union[bool, str] = "auto",
        data_async: bool = False,
        data_delay: int = 0,
    ):
        from repro.models.model import init_model
        from repro.optim.base import apply_updates, clip_by_global_norm
        from repro.optim.factory import build_optimizer
        from repro.pipeline.delay import stage_delayed_optimizer

        # precision policy rewrites the config's dtypes (None = leave the
        # caller's cfg untouched); use_kernels additionally routes the fused
        # flash attention into the stage apply via ModelConfig.use_kernels
        if isinstance(precision, str):
            precision = PRECISION_POLICIES[precision]
        if precision is not None:
            cfg = precision.apply(cfg)
        self.precision = (
            precision.name if precision is not None
            else ("bf16_compute" if cfg.dtype == "bfloat16" else "f32")
        )
        if use_kernels:
            cfg = cfg.replace(use_kernels=True)
        self.cfg = cfg
        self.schedule = schedule
        self.num_stages = K = num_stages
        self.num_microbatches = M = num_microbatches or num_stages
        if topology is None:
            topology = (
                Topology.from_mesh(mesh) if mesh is not None
                else Topology.from_device_count(K)
            )
        if topology.stages != K:
            raise ValueError(
                f"topology {topology.describe()} has {topology.stages} stages "
                f"but the engine was asked for {K}"
            )
        self.topology = topology
        # multi-controller: the device grid must be process-slab ordered for
        # the data-shard and checkpoint-ownership maps to be meaningful
        from repro.launch.distributed import assert_process_slabs, process_count

        self._num_processes = process_count()
        if self._num_processes > 1:
            assert_process_slabs()
            topology.local_device_count(self._num_processes)  # divisibility
        self.mesh = mesh if mesh is not None else topology.make_mesh()

        # -- asynchronous data axis (DESIGN.md §12) -------------------------
        # D > 0 takes the cross-replica gradient all-reduce off the step
        # critical path: the step program differentiates per replica (no
        # (pod, data) collective anywhere inside it) and applies the D-step-
        # old deferred reduction from the engine-level FIFO; a separate
        # reduce program — the ONLY place the data all-reduce exists — folds
        # the fresh local gradients and is consumed D steps later.
        # D == 0 gates to the synchronous path at CONSTRUCTION time (same
        # step program, optimizer tree and checkpoint layout), so
        # ``data_async=True, data_delay=0`` is bit-identical to sync.
        self.data_async = bool(data_async)
        self.data_delay = int(data_delay)
        if self.data_delay < 0:
            raise ValueError(f"data_delay must be >= 0, got {self.data_delay}")
        if self.data_delay > 0 and not self.data_async:
            raise ValueError("data_delay > 0 requires data_async=True")
        self._data_eff = self.data_async and self.data_delay > 0
        D = self.data_delay if self._data_eff else 0

        self.grad_fn = make_pipeline_grad(
            cfg, self.mesh, K, M, schedule=schedule,
            data_axis=topology.schedule_data_axis,
        )

        # stage context from parameter SHAPES only — no device arrays yet
        shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
        stacked_s, shared_s = jax.eval_shape(
            lambda p: stack_stage_params(p, cfg, K), shapes
        )
        # delay-aware bases (pipedream_lr, nesterov_pp, stage-aware rotation
        # refresh) see the TOTAL per-leaf staleness tau_k + D via the context
        ctx = stage_context_for_stacked(stacked_s, shared_s, K, data_delay=D)
        base = build_optimizer(ocfg, (stacked_s, shared_s), cfg,
                               num_stages=K, apply_delay=False,
                               use_kernels=use_kernels, stage_context=ctx)
        if async_grads and (K > 1 or self._data_eff):
            self.opt = stage_delayed_optimizer(
                base, ctx.delay_specs(), K,
                store_params=(ocfg.name == "delay_compensation"),
                extra_param_delay=D,
            )
        else:
            self.opt = base

        def _step(stacked, shared, opt_state, batch, t):
            loss, grads = self.grad_fn(stacked, shared, batch)
            if grad_clip:
                grads = clip_by_global_norm(grads, grad_clip)
            updates, opt_state = self.opt.update(
                grads, opt_state, (stacked, shared), t
            )
            stacked = apply_updates(stacked, updates[0])
            shared = apply_updates(shared, updates[1])
            return stacked, shared, opt_state, loss

        if self._data_eff:
            self._local_grad_fn = make_pipeline_grad(
                cfg, self.mesh, K, M, schedule=schedule,
                data_axis=topology.schedule_data_axis, reduce_data=False,
            )

            def _step_async(stacked, shared, opt_state, gbar, batch, t):
                # fresh per-replica loss + local grads; the deferred global
                # mean ``gbar`` (from D steps ago) is what gets applied —
                # clip and optimizer chain identical to the sync step
                loss_r, local = self._local_grad_fn(stacked, shared, batch)
                grads = gbar
                if grad_clip:
                    grads = clip_by_global_norm(grads, grad_clip)
                updates, opt_state = self.opt.update(
                    grads, opt_state, (stacked, shared), t
                )
                stacked = apply_updates(stacked, updates[0])
                shared = apply_updates(shared, updates[1])
                return stacked, shared, opt_state, loss_r, local

            # reduce program: mean over the leading replica axis — lowered
            # with replicated/stage-sharded out_shardings, this is the one
            # place XLA emits the (pod, data)-grouped all-reduce
            def _reduce(loss_r, local):
                gs, gsh = local
                mean0 = lambda a: jnp.mean(a, axis=0)
                return jnp.mean(loss_r), (
                    jax.tree.map(mean0, gs), jax.tree.map(mean0, gsh),
                )

            stage_sh = NamedSharding(self.mesh, PartitionSpec("stage"))
            repl_sh = NamedSharding(self.mesh, PartitionSpec())
            gbar_shardings = (
                jax.tree.map(lambda _: stage_sh, stacked_s),
                jax.tree.map(lambda _: repl_sh, shared_s),
            )
            # in_shardings pin the local-grad layout the step program emits
            # (leading replica axis over the data axes) — without them an
            # abstract lowering would treat the inputs as replicated and the
            # audited reduce HLO would lose its all-reduce
            dax = topology.schedule_data_axis
            rep_sh = NamedSharding(self.mesh, PartitionSpec(dax))
            rep_stage_sh = NamedSharding(self.mesh, PartitionSpec(dax, "stage"))
            local_shardings = (
                jax.tree.map(lambda _: rep_stage_sh, stacked_s),
                jax.tree.map(lambda _: rep_sh, shared_s),
            )
            self._reduce_fn = _reduce
            self._reduce_in_shardings = (rep_sh, local_shardings)
            self._jit_reduce = jax.jit(
                _reduce,
                in_shardings=self._reduce_in_shardings,
                out_shardings=(repl_sh, gbar_shardings),
            )

            def _zeros():
                z = lambda p: jnp.zeros(p.shape, p.dtype)
                return (
                    jax.tree.map(z, stacked_s), jax.tree.map(z, shared_s),
                )

            # jitted with explicit out_shardings so multi-process runs build
            # the warm-up zeros as GLOBAL arrays over the shared mesh
            self._zero_gbar = jax.jit(_zeros, out_shardings=gbar_shardings)

        self._step_fn = (
            _step_async if self._data_eff else _step
        )  # raw step, kept for the static analyzer
        # donate the stacked params, shared params, and optimizer state
        # (which carries the delay-FIFO queues) into the jitted step: XLA
        # updates them in place instead of copying every leaf each step.
        # Safe because the loop always threads the RETURNED state forward and
        # checkpoints snapshot to host before the next step is dispatched
        # (DESIGN.md §11); `donate=False` keeps the copying step for
        # donation-on/off benchmarks and the analyzer's mutation tests.
        # "auto" resolves per platform: ON where donation removes per-step
        # copies and halves transient param/opt memory (tpu, gpu), OFF on
        # the XLA:CPU thunk runtime where in-place aliasing serializes the
        # schedule and measurably SLOWS the step ~10-20% (DESIGN.md §11
        # known limits) — the analyzer still audits a donate=True compile
        # on every host so the aliasing invariant cannot rot off-TPU.
        if donate == "auto":
            donate = jax.default_backend() in ("tpu", "gpu")
        self.donate = bool(donate)
        # donated argnums stay (0, 1, 2) in async mode too: gbar (arg 3) is
        # still referenced from the FIFO list until the engine drops it, so
        # it must NOT be donated
        self._jit_step = (
            jax.jit(self._step_fn, donate_argnums=(0, 1, 2)) if self.donate
            else jax.jit(self._step_fn)
        )
        self._stage_shapes = (stacked_s, shared_s)

    def init_state(self, params: Any = None, key: Any = None) -> EngineState:
        from repro.models.model import init_model

        if params is None:
            params = init_model(key if key is not None else jax.random.PRNGKey(0),
                                self.cfg)
        stacked, shared = stack_stage_params(params, self.cfg, self.num_stages)
        fifo = None
        if self._data_eff:
            # warm-up: the first D steps apply zero reductions — the exact
            # analogue of the delay FIFO's zero-gradient warm-up
            zero = self._zero_gbar()
            fifo = [zero for _ in range(self.data_delay)]
        return EngineState(
            params=(stacked, shared),
            opt_state=self.opt.init((stacked, shared)),
            data_fifo=fifo,
        )

    def _shape_batch(self, batch: Dict) -> Dict:
        """(B, S) host batch -> (M, B//M, S) microbatched pipeline input.

        Multi-controller runs feed the PROCESS-LOCAL slice instead
        (`data.synthetic.process_local_batches`: already microbatched, only
        this process's data-shard rows); the global array is assembled from
        every process's addressable rows via
        `jax.make_array_from_process_local_data` — no process ever holds the
        full batch.
        """
        if self._num_processes > 1:
            return self._assemble_process_batch(batch)
        tokens = batch["tokens"]
        if tokens.ndim == 3:  # already microbatched
            mb = tokens.shape[1]
        else:
            M = self.num_microbatches
            B, S = tokens.shape
            assert B % M == 0, f"batch {B} must divide into {M} microbatches"
            mb = B // M
            batch = {
                "tokens": tokens.reshape(M, mb, S),
                "labels": batch["labels"].reshape(M, mb, S),
            }
        shards = self.topology.data_shards
        assert mb % shards == 0, (
            f"microbatch size {mb} must divide over the {shards} data shards "
            f"of topology {self.topology.describe()}"
        )
        return batch

    def _assemble_process_batch(self, batch: Dict) -> Dict:
        """Process-local (M, mb_local, ...) rows -> global jax.Array sharded
        over the topology's data axes."""
        import numpy as np
        from jax.sharding import NamedSharding

        from repro.launch.distributed import process_index

        topo = self.topology
        lo, hi = topo.process_data_shards(self._num_processes, process_index())
        sharding = NamedSharding(self.mesh, topo.batch_spec())
        out = {}
        for k, v in batch.items():
            v = np.asarray(v)
            assert v.ndim >= 2 and v.shape[0] == self.num_microbatches, (
                f"multi-process batches must arrive microbatched "
                f"(process_local_batches); got {k} of shape {v.shape}"
            )
            mb_local = v.shape[1]
            assert mb_local % (hi - lo) == 0, (
                f"local microbatch rows {mb_local} do not cover data shards "
                f"[{lo}, {hi}) of topology {topo.describe()}"
            )
            mb = mb_local // (hi - lo) * topo.data_shards
            out[k] = jax.make_array_from_process_local_data(
                sharding, v, (v.shape[0], mb, *v.shape[2:])
            )
        return out

    def step(
        self, state: EngineState, batch: Dict, t: int
    ) -> Tuple[EngineState, Any, Dict]:
        stacked, shared = state.params
        batch = self._shape_batch(batch)
        if not self._data_eff:
            stacked, shared, opt_state, loss = self._jit_step(
                stacked, shared, state.opt_state, batch, jnp.int32(t)
            )
            return (
                EngineState((stacked, shared), opt_state),
                loss,
                {"ce": loss},
            )
        # async data axis: pop the D-step-old reduction, step with it, then
        # dispatch the reduce of this step's fresh local grads and enqueue
        # it. Both programs are async-dispatched, and since nothing needs
        # the reduce result for D more steps, the all-reduce overlaps with
        # the next steps' compute instead of serializing each one.
        fifo = list(state.data_fifo)
        gbar = fifo.pop(0)
        stacked, shared, opt_state, loss_r, local = self._jit_step(
            stacked, shared, state.opt_state, gbar, batch, jnp.int32(t)
        )
        loss, reduced = self._jit_reduce(loss_r, local)
        fifo.append(reduced)
        return (
            EngineState((stacked, shared), opt_state, data_fifo=fifo),
            loss,
            {"ce": loss},
        )

    # -- static-analysis hooks (repro.analysis, DESIGN.md §8) ---------------

    def abstract_step_args(
        self, seq_len: int = 8, microbatch_size: int = 0
    ) -> Tuple:
        """ShapeDtypeStructs for one ``_step`` call — the analyzer traces
        and lowers the REAL engine step on these, no device arrays built.

        ``microbatch_size`` defaults to the smallest batch the topology
        admits (one row per data shard).
        """
        mb = microbatch_size or self.topology.data_shards
        stacked_s, shared_s = self._stage_shapes
        opt_s = jax.eval_shape(self.opt.init, (stacked_s, shared_s))
        tok = jax.ShapeDtypeStruct(
            (self.num_microbatches, mb, seq_len), jnp.int32
        )
        batch = {"tokens": tok, "labels": tok}
        t = jax.ShapeDtypeStruct((), jnp.int32)
        if self._data_eff:
            gbar_s = jax.eval_shape(self._zero_gbar)
            return stacked_s, shared_s, opt_s, gbar_s, batch, t
        return stacked_s, shared_s, opt_s, batch, t

    def step_jaxpr(self, seq_len: int = 8, microbatch_size: int = 0):
        """ClosedJaxpr of the full train step (grads + clip + optimizer +
        delay FIFO) exactly as `step` jits it."""
        args = self.abstract_step_args(seq_len, microbatch_size)
        return jax.make_jaxpr(self._step_fn)(*args)

    def compiled_step(self, seq_len: int = 8, microbatch_size: int = 0):
        """Compiled executable of the step — its optimized HLO
        (`.as_text()`) is what the collective auditor parses."""
        args = self.abstract_step_args(seq_len, microbatch_size)
        return self._jit_step.lower(*args).compile()

    def compiled_reduce(self, seq_len: int = 8, microbatch_size: int = 0):
        """Compiled executable of the deferred data-reduction program (async
        data mode only) — the ONE program that may contain the
        ``(pod, data)``-grouped gradient all-reduce. The analyzer audits the
        step/reduce pair with `analysis.hlo.check_async_step_reduction`."""
        assert self._data_eff, "compiled_reduce requires data_async + D > 0"
        stacked_s, shared_s, _opt, _gbar, batch, _t = self.abstract_step_args(
            seq_len, microbatch_size
        )
        loss_r_s, local_s = jax.eval_shape(
            self._local_grad_fn, stacked_s, shared_s, batch
        )
        return self._jit_reduce.lower(loss_r_s, local_s).compile()

    def donated_leaf_indices(self) -> Tuple[List[int], List[int]]:
        """(expected_aliased, queue_leaves): flattened HLO parameter indices
        of the donated jit arguments (stacked, shared, opt_state).

        Every donated leaf must appear in the compiled module's
        ``input_output_alias`` EXCEPT the delay-FIFO queue leaves
        (``grad_q``/``param_q`` in `pipeline.delay.stage_delayed_optimizer`
        state): their in-program roll (`jnp.roll`-style shift of the queue
        axis) makes XLA decline the alias, which is correct behaviour — jax
        lowers them as ``jax.buffer_donor`` (XLA's choice) rather than the
        pinned ``tf.aliasing_output``. The analyzer's donation check
        (`analysis.hlo.check_donation`) asserts the first set is aliased.
        """
        import jax.tree_util as jtu

        stacked_s, shared_s = self._stage_shapes
        opt_s = jax.eval_shape(self.opt.init, (stacked_s, shared_s))
        flat = jtu.tree_flatten_with_path((stacked_s, shared_s, opt_s))[0]
        expected: List[int] = []
        queues: List[int] = []
        for i, (path, _) in enumerate(flat):
            keys = jtu.keystr(path)
            if "grad_q" in keys or "param_q" in keys:
                queues.append(i)
            else:
                expected.append(i)
        return expected, queues

    def canonical_params(self, state: EngineState) -> Dict:
        """Unstacked (per-layer) parameter tree, e.g. for evaluation."""
        stacked, shared = state.params
        return unstack_stage_params(stacked, shared, self.cfg)

    def checkpoint_tree(self, state: EngineState) -> Any:
        """Async data mode appends the in-flight reduction FIFO as a third
        element, so a resumed run replays the exact same deferred gradients
        (bitwise resume). The sync layout stays the 2-tuple the base class
        defines — a ``--data-delay 0`` checkpoint is byte-identical to a
        synchronous one."""
        if self._data_eff:
            return (state.params, state.opt_state, tuple(state.data_fifo))
        return (state.params, state.opt_state)

    def load_state(self, tree: Any) -> EngineState:
        if len(tree) == 3:
            params, opt_state, fifo = tree
            fifo = list(fifo)
        else:
            params, opt_state = tree
            fifo = None
        if self._data_eff:
            if fifo is None:
                # warm-starting an async run from a synchronous checkpoint:
                # the first D steps replay the zero-gradient warm-up
                fifo = [self._zero_gbar() for _ in range(self.data_delay)]
            if len(fifo) != self.data_delay:
                raise ValueError(
                    f"checkpoint FIFO depth {len(fifo)} does not match "
                    f"data_delay={self.data_delay}"
                )
        else:
            fifo = None
        return EngineState(params=params, opt_state=opt_state, data_fifo=fifo)

    def checkpoint_job(
        self, path: str, state: EngineState, step: int = 0,
        meta: Optional[Dict] = None,
    ):
        """Per-stage-shard save: one arrays file per pipeline stage.

        Each leaf's shard axis is read from its live `NamedSharding` (the
        stacked params/moments on axis 0, the delay-FIFO queues on their
        stage axis); leaves the runtime replicates — shared params, scalar
        counters, anything saved before the first compiled step — go to
        shard 0. No gather-to-host of the stage-sharded state.

        Split per the `PipelineEngine.checkpoint_job` contract: the
        `snapshot_sharded` half runs NOW (it needs the live sharding
        metadata, and the donated step may reuse these buffers as soon as
        the loop dispatches the next step); the returned closure performs
        only file I/O + barriers and may run on a background writer.

        Multi-controller: every process calls this at the same step; each
        writes only the shards `Topology.shard_owners` assigns it (sliced
        from locally addressable device shards), the main process alone
        commits the manifest, and the distributed barrier orders
        name-scan -> shard writes -> manifest -> GC across processes. The
        barriers live in the WRITE half, so async writers must drain jobs
        in submission order on every process (engine.loop's single serial
        writer thread).
        """
        from repro.checkpoint import snapshot_sharded, write_sharded_checkpoint
        from repro.launch.distributed import barrier, is_main, process_index

        owned = None
        kw = {}
        if self._num_processes > 1:
            owners = self.topology.shard_owners(self._num_processes)
            me = process_index()
            owned = [s for s, p in enumerate(owners) if p == me]
            kw = dict(write_manifest=is_main(), barrier=barrier)
        snapshot = snapshot_sharded(
            self.checkpoint_tree(state), num_shards=self.num_stages,
            owned_shards=owned,
        )
        full_meta = {"topology": self.topology.describe(),
                     "precision": self.precision,
                     "num_processes": self._num_processes, **(meta or {})}

        def write() -> None:
            write_sharded_checkpoint(path, snapshot, step=step,
                                     meta=full_meta, **kw)

        return write
