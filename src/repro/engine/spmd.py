"""Distributed pipeline backend: `shard_map` over a `stage` mesh axis.

TPU adaptation of PipeDream (DESIGN.md §3): activations move between
neighbouring stages with `jax.lax.ppermute` inside one jitted program. The
tick schedules live in `repro.engine.schedules` behind one interface —
``fill_drain`` (forward scan + autodiff backward, O(M) activation buffer) and
``1f1b`` (interleaved explicit forward/backward ticks, O(K) activation
stash). Both scan the tick body, so the traced program is O(1) in both
microbatches and stages — the jaxpr for M=64 is the same size as for M=4.

Staleness (the async part) is applied by composing the resulting gradient
with the per-stage delay FIFO (`repro.pipeline.delay.stage_delayed_optimizer`)
— deterministic PipeDream weight-stashing semantics on SPMD hardware. Stage k
applies the gradient computed tau_k = K-1-k steps ago; sharded over `stage`,
each device's FIFO slice holds exactly its own stage's stash (linear-in-depth
memory, paper Section 4.3).

The pipeline runtime targets homogeneous decoder stacks (the paper's models):
layers are split contiguously into K equal stages, each device along the
`stage` axis holds its stage's layer stack; embedding / final norm / LM head
are replicated and only contribute on the first/last stage.

`SpmdEngine` packages all of it — pipeline grads, per-stage delay, any
`build_optimizer` base — behind the `PipelineEngine` interface so the shared
loop (and `repro.launch.train --backend spmd`) drives it end to end.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import (
    PRECISION_POLICIES,
    ModelConfig,
    OptimizerConfig,
    PrecisionPolicy,
)
from repro.engine.base import EngineState, PipelineEngine
from repro.engine.schedules import make_fill_drain_loss, make_schedule_grad
from repro.launch.topology import Topology
from repro.pipeline.partition import FIRST_STAGE_SHARED, stage_context_for_stacked

# Backwards-compatible alias; the canonical list lives with the partition
# helpers (`repro.pipeline.partition.FIRST_STAGE_SHARED`).
_FIRST_STAGE_SHARED = FIRST_STAGE_SHARED


def stack_stage_params(params: Dict, cfg: ModelConfig, num_stages: int) -> Tuple[Dict, Dict]:
    """Split an unstacked model into (stage_stacked_blocks, shared).

    stage_stacked leaves: (K, layers_per_stage, ...); shared = embedding,
    positional embedding, final norm, LM head (replicated).
    """
    assert not cfg.scan_layers, "pipeline stacking starts from per-layer params"
    L = cfg.num_layers
    assert L % num_stages == 0, "layers must divide evenly across stages"
    per = L // num_stages
    blocks = params["blocks"]
    # stack layers within a stage, then stack stages
    stages = []
    for k in range(num_stages):
        layer_group = blocks[k * per : (k + 1) * per]
        stages.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layer_group))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *stages)
    shared = {k: v for k, v in params.items() if k != "blocks"}
    return stacked, shared


def unstack_stage_params(stacked: Dict, shared: Dict, cfg: ModelConfig) -> Dict:
    K = jax.tree.leaves(stacked)[0].shape[0]
    per = jax.tree.leaves(stacked)[0].shape[1]
    blocks = []
    for k in range(K):
        for l in range(per):
            blocks.append(jax.tree.map(lambda x: x[k, l], stacked))
    out = dict(shared)
    out["blocks"] = tuple(blocks)
    return out


def make_pipeline_loss(
    cfg: ModelConfig,
    mesh: Mesh,
    num_stages: int,
    num_microbatches: int,
    stage_axis: str = "stage",
    data_axis: str = "data",
):
    """Fill-drain loss_fn(stage_params, shared, batch) -> scalar.

    Only the fill-drain schedule has a standalone differentiable loss; the
    1F1B schedule builds its gradient explicitly — use ``make_pipeline_grad``
    with ``schedule="1f1b"`` for it.
    """
    return make_fill_drain_loss(
        cfg, mesh, num_stages, num_microbatches,
        stage_axis=stage_axis, data_axis=data_axis,
    )


def make_pipeline_grad(
    cfg, mesh, num_stages, num_microbatches, schedule: str = "fill_drain", **kw
):
    """grad_fn(stage_params, shared, batch) -> (loss, (g_stacked, g_shared))
    under the chosen tick schedule (``"fill_drain"`` or ``"1f1b"``)."""
    return make_schedule_grad(
        cfg, mesh, num_stages, num_microbatches, schedule=schedule, **kw
    )


# ---------------------------------------------------------------------------
# Delay specs for the stage-stacked parameter layout
# ---------------------------------------------------------------------------


def spmd_delay_specs(
    stacked: Any, shared: Any, num_stages: int
) -> List[Union[int, str]]:
    """Per-leaf delay spec for the (stacked, shared) tuple, ordered like
    ``jax.tree_util.tree_flatten((stacked, shared))``.

    Thin wrapper over `stage_context_for_stacked` — the partition module owns
    the stacked/shared delay rules; this re-export survives for callers of
    the pre-StageContext API.
    """
    return stage_context_for_stacked(stacked, shared, num_stages).delay_specs()


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class SpmdEngine(PipelineEngine):
    """Complete async SPMD train step: pipeline grads composed with the
    per-stage delay FIFO around any `build_optimizer` base.

    ``schedule`` picks the tick schedule: ``"fill_drain"`` (O(M) activation
    buffer per stage) or ``"1f1b"`` (O(K) stash). Both produce the same
    synchronous gradient to fp32 tolerance, so either composes unchanged with
    the delay FIFO. ``async_grads=False`` drops the delay wrapper — the
    synchronous-gradient reference used to cross-check the two backends
    against each other.

    The optimizer is built from a stacked-layout `StageContext`
    (`stage_context_for_stacked`), so every `build_optimizer` base runs
    natively on the ``(K, per, ...)`` leaves: stage-aware rotation
    frequencies vectorize over the leading stage axis, PipeDream-LR scales
    per stage slice, and delay compensation reads the per-stage stale weight
    snapshot the FIFO queues (``store_params``). ``use_kernels=True`` routes
    the basis-rotation matmuls and the fused Adam scale through the Pallas
    kernels (`repro.kernels.ops`), interpreted off-TPU.

    ``topology`` places the engine on a `(pod, stage, data)` device layout
    (`repro.launch.topology.Topology`): the mesh comes from
    ``topology.make_mesh()`` and the gradient/loss data reduction spans
    every data axis — ``("pod", "data")`` on multi-pod shapes. ``mesh`` is
    still accepted for callers that pre-built one (its topology is recovered
    via `Topology.from_mesh`); with neither, the engine uses every visible
    device as a single-pod ``(stage, data)`` layout.
    """

    name = "spmd"

    def __init__(
        self,
        cfg: ModelConfig,
        ocfg: OptimizerConfig,
        num_stages: int,
        num_microbatches: int = 0,
        mesh: Optional[Mesh] = None,
        grad_clip: float = 1.0,
        async_grads: bool = True,
        schedule: str = "fill_drain",
        use_kernels: bool = False,
        topology: Optional[Topology] = None,
        precision: Union[str, PrecisionPolicy, None] = None,
        donate: Union[bool, str] = "auto",
    ):
        from repro.models.model import init_model
        from repro.optim.base import apply_updates, clip_by_global_norm
        from repro.optim.factory import build_optimizer
        from repro.pipeline.delay import stage_delayed_optimizer

        # precision policy rewrites the config's dtypes (None = leave the
        # caller's cfg untouched); use_kernels additionally routes the fused
        # flash attention into the stage apply via ModelConfig.use_kernels
        if isinstance(precision, str):
            precision = PRECISION_POLICIES[precision]
        if precision is not None:
            cfg = precision.apply(cfg)
        self.precision = (
            precision.name if precision is not None
            else ("bf16_compute" if cfg.dtype == "bfloat16" else "f32")
        )
        if use_kernels:
            cfg = cfg.replace(use_kernels=True)
        self.cfg = cfg
        self.schedule = schedule
        self.num_stages = K = num_stages
        self.num_microbatches = M = num_microbatches or num_stages
        if topology is None:
            topology = (
                Topology.from_mesh(mesh) if mesh is not None
                else Topology.from_device_count(K)
            )
        if topology.stages != K:
            raise ValueError(
                f"topology {topology.describe()} has {topology.stages} stages "
                f"but the engine was asked for {K}"
            )
        self.topology = topology
        # multi-controller: the device grid must be process-slab ordered for
        # the data-shard and checkpoint-ownership maps to be meaningful
        from repro.launch.distributed import assert_process_slabs, process_count

        self._num_processes = process_count()
        if self._num_processes > 1:
            assert_process_slabs()
            topology.local_device_count(self._num_processes)  # divisibility
        self.mesh = mesh if mesh is not None else topology.make_mesh()
        self.grad_fn = make_pipeline_grad(
            cfg, self.mesh, K, M, schedule=schedule,
            data_axis=topology.schedule_data_axis,
        )

        # stage context from parameter SHAPES only — no device arrays yet
        shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
        stacked_s, shared_s = jax.eval_shape(
            lambda p: stack_stage_params(p, cfg, K), shapes
        )
        ctx = stage_context_for_stacked(stacked_s, shared_s, K)
        base = build_optimizer(ocfg, (stacked_s, shared_s), cfg,
                               num_stages=K, apply_delay=False,
                               use_kernels=use_kernels, stage_context=ctx)
        if async_grads and K > 1:
            self.opt = stage_delayed_optimizer(
                base, ctx.delay_specs(), K,
                store_params=(ocfg.name == "delay_compensation"),
            )
        else:
            self.opt = base

        def _step(stacked, shared, opt_state, batch, t):
            loss, grads = self.grad_fn(stacked, shared, batch)
            if grad_clip:
                grads = clip_by_global_norm(grads, grad_clip)
            updates, opt_state = self.opt.update(
                grads, opt_state, (stacked, shared), t
            )
            stacked = apply_updates(stacked, updates[0])
            shared = apply_updates(shared, updates[1])
            return stacked, shared, opt_state, loss

        self._step_fn = _step  # raw step, kept for the static analyzer
        # donate the stacked params, shared params, and optimizer state
        # (which carries the delay-FIFO queues) into the jitted step: XLA
        # updates them in place instead of copying every leaf each step.
        # Safe because the loop always threads the RETURNED state forward and
        # checkpoints snapshot to host before the next step is dispatched
        # (DESIGN.md §11); `donate=False` keeps the copying step for
        # donation-on/off benchmarks and the analyzer's mutation tests.
        # "auto" resolves per platform: ON where donation removes per-step
        # copies and halves transient param/opt memory (tpu, gpu), OFF on
        # the XLA:CPU thunk runtime where in-place aliasing serializes the
        # schedule and measurably SLOWS the step ~10-20% (DESIGN.md §11
        # known limits) — the analyzer still audits a donate=True compile
        # on every host so the aliasing invariant cannot rot off-TPU.
        if donate == "auto":
            donate = jax.default_backend() in ("tpu", "gpu")
        self.donate = bool(donate)
        self._jit_step = (
            jax.jit(_step, donate_argnums=(0, 1, 2)) if self.donate
            else jax.jit(_step)
        )
        self._stage_shapes = (stacked_s, shared_s)

    def init_state(self, params: Any = None, key: Any = None) -> EngineState:
        from repro.models.model import init_model

        if params is None:
            params = init_model(key if key is not None else jax.random.PRNGKey(0),
                                self.cfg)
        stacked, shared = stack_stage_params(params, self.cfg, self.num_stages)
        return EngineState(
            params=(stacked, shared), opt_state=self.opt.init((stacked, shared))
        )

    def _shape_batch(self, batch: Dict) -> Dict:
        """(B, S) host batch -> (M, B//M, S) microbatched pipeline input.

        Multi-controller runs feed the PROCESS-LOCAL slice instead
        (`data.synthetic.process_local_batches`: already microbatched, only
        this process's data-shard rows); the global array is assembled from
        every process's addressable rows via
        `jax.make_array_from_process_local_data` — no process ever holds the
        full batch.
        """
        if self._num_processes > 1:
            return self._assemble_process_batch(batch)
        tokens = batch["tokens"]
        if tokens.ndim == 3:  # already microbatched
            mb = tokens.shape[1]
        else:
            M = self.num_microbatches
            B, S = tokens.shape
            assert B % M == 0, f"batch {B} must divide into {M} microbatches"
            mb = B // M
            batch = {
                "tokens": tokens.reshape(M, mb, S),
                "labels": batch["labels"].reshape(M, mb, S),
            }
        shards = self.topology.data_shards
        assert mb % shards == 0, (
            f"microbatch size {mb} must divide over the {shards} data shards "
            f"of topology {self.topology.describe()}"
        )
        return batch

    def _assemble_process_batch(self, batch: Dict) -> Dict:
        """Process-local (M, mb_local, ...) rows -> global jax.Array sharded
        over the topology's data axes."""
        import numpy as np
        from jax.sharding import NamedSharding

        from repro.launch.distributed import process_index

        topo = self.topology
        lo, hi = topo.process_data_shards(self._num_processes, process_index())
        sharding = NamedSharding(self.mesh, topo.batch_spec())
        out = {}
        for k, v in batch.items():
            v = np.asarray(v)
            assert v.ndim >= 2 and v.shape[0] == self.num_microbatches, (
                f"multi-process batches must arrive microbatched "
                f"(process_local_batches); got {k} of shape {v.shape}"
            )
            mb_local = v.shape[1]
            assert mb_local % (hi - lo) == 0, (
                f"local microbatch rows {mb_local} do not cover data shards "
                f"[{lo}, {hi}) of topology {topo.describe()}"
            )
            mb = mb_local // (hi - lo) * topo.data_shards
            out[k] = jax.make_array_from_process_local_data(
                sharding, v, (v.shape[0], mb, *v.shape[2:])
            )
        return out

    def step(
        self, state: EngineState, batch: Dict, t: int
    ) -> Tuple[EngineState, Any, Dict]:
        stacked, shared = state.params
        stacked, shared, opt_state, loss = self._jit_step(
            stacked, shared, state.opt_state, self._shape_batch(batch), jnp.int32(t)
        )
        return (
            EngineState((stacked, shared), opt_state),
            loss,
            {"ce": loss},
        )

    # -- static-analysis hooks (repro.analysis, DESIGN.md §8) ---------------

    def abstract_step_args(
        self, seq_len: int = 8, microbatch_size: int = 0
    ) -> Tuple:
        """ShapeDtypeStructs for one ``_step`` call — the analyzer traces
        and lowers the REAL engine step on these, no device arrays built.

        ``microbatch_size`` defaults to the smallest batch the topology
        admits (one row per data shard).
        """
        mb = microbatch_size or self.topology.data_shards
        stacked_s, shared_s = self._stage_shapes
        opt_s = jax.eval_shape(self.opt.init, (stacked_s, shared_s))
        tok = jax.ShapeDtypeStruct(
            (self.num_microbatches, mb, seq_len), jnp.int32
        )
        batch = {"tokens": tok, "labels": tok}
        t = jax.ShapeDtypeStruct((), jnp.int32)
        return stacked_s, shared_s, opt_s, batch, t

    def step_jaxpr(self, seq_len: int = 8, microbatch_size: int = 0):
        """ClosedJaxpr of the full train step (grads + clip + optimizer +
        delay FIFO) exactly as `step` jits it."""
        args = self.abstract_step_args(seq_len, microbatch_size)
        return jax.make_jaxpr(self._step_fn)(*args)

    def compiled_step(self, seq_len: int = 8, microbatch_size: int = 0):
        """Compiled executable of the step — its optimized HLO
        (`.as_text()`) is what the collective auditor parses."""
        args = self.abstract_step_args(seq_len, microbatch_size)
        return self._jit_step.lower(*args).compile()

    def donated_leaf_indices(self) -> Tuple[List[int], List[int]]:
        """(expected_aliased, queue_leaves): flattened HLO parameter indices
        of the donated jit arguments (stacked, shared, opt_state).

        Every donated leaf must appear in the compiled module's
        ``input_output_alias`` EXCEPT the delay-FIFO queue leaves
        (``grad_q``/``param_q`` in `pipeline.delay.stage_delayed_optimizer`
        state): their in-program roll (`jnp.roll`-style shift of the queue
        axis) makes XLA decline the alias, which is correct behaviour — jax
        lowers them as ``jax.buffer_donor`` (XLA's choice) rather than the
        pinned ``tf.aliasing_output``. The analyzer's donation check
        (`analysis.hlo.check_donation`) asserts the first set is aliased.
        """
        import jax.tree_util as jtu

        stacked_s, shared_s = self._stage_shapes
        opt_s = jax.eval_shape(self.opt.init, (stacked_s, shared_s))
        flat = jtu.tree_flatten_with_path((stacked_s, shared_s, opt_s))[0]
        expected: List[int] = []
        queues: List[int] = []
        for i, (path, _) in enumerate(flat):
            keys = jtu.keystr(path)
            if "grad_q" in keys or "param_q" in keys:
                queues.append(i)
            else:
                expected.append(i)
        return expected, queues

    def canonical_params(self, state: EngineState) -> Dict:
        """Unstacked (per-layer) parameter tree, e.g. for evaluation."""
        stacked, shared = state.params
        return unstack_stage_params(stacked, shared, self.cfg)

    def checkpoint_job(
        self, path: str, state: EngineState, step: int = 0,
        meta: Optional[Dict] = None,
    ):
        """Per-stage-shard save: one arrays file per pipeline stage.

        Each leaf's shard axis is read from its live `NamedSharding` (the
        stacked params/moments on axis 0, the delay-FIFO queues on their
        stage axis); leaves the runtime replicates — shared params, scalar
        counters, anything saved before the first compiled step — go to
        shard 0. No gather-to-host of the stage-sharded state.

        Split per the `PipelineEngine.checkpoint_job` contract: the
        `snapshot_sharded` half runs NOW (it needs the live sharding
        metadata, and the donated step may reuse these buffers as soon as
        the loop dispatches the next step); the returned closure performs
        only file I/O + barriers and may run on a background writer.

        Multi-controller: every process calls this at the same step; each
        writes only the shards `Topology.shard_owners` assigns it (sliced
        from locally addressable device shards), the main process alone
        commits the manifest, and the distributed barrier orders
        name-scan -> shard writes -> manifest -> GC across processes. The
        barriers live in the WRITE half, so async writers must drain jobs
        in submission order on every process (engine.loop's single serial
        writer thread).
        """
        from repro.checkpoint import snapshot_sharded, write_sharded_checkpoint
        from repro.launch.distributed import barrier, is_main, process_index

        owned = None
        kw = {}
        if self._num_processes > 1:
            owners = self.topology.shard_owners(self._num_processes)
            me = process_index()
            owned = [s for s, p in enumerate(owners) if p == me]
            kw = dict(write_manifest=is_main(), barrier=barrier)
        snapshot = snapshot_sharded(
            self.checkpoint_tree(state), num_shards=self.num_stages,
            owned_shards=owned,
        )
        full_meta = {"topology": self.topology.describe(),
                     "precision": self.precision,
                     "num_processes": self._num_processes, **(meta or {})}

        def write() -> None:
            write_sharded_checkpoint(path, snapshot, step=step,
                                     meta=full_meta, **kw)

        return write
