"""The single training loop shared by every driver.

Previously the history / stale-params bookkeeping, checkpoint-resume,
incremental-JSON metrics and logging lived in near-identical copies inside
`launch/train.py`, `pipeline/simulate.py`, the benchmarks and the examples.
They now live here once, driving any `PipelineEngine` backend.

    engine = SimEngine(cfg, opt, ...)            # or SpmdEngine(...)
    state, losses = run_loop(engine, data_iter, LoopConfig(steps=300))

Checkpoint layout is unchanged from the pre-engine driver ((params,
opt_state) + step in the manifest), so old checkpoints resume under the loop.

Host I/O stays off the step critical path (DESIGN.md §11):

* the per-step loss is NOT fetched to host every iteration — device scalars
  accumulate in a pending list and are converted in one batch at the
  log/metrics cadence, so the loop never forces a device sync per step
  (this alone helps both backends, donation or not);
* checkpoints are snapshotted to host synchronously (`engine.checkpoint_job`
  — cheap device->host copies that must precede the next donated step) but
  WRITTEN on a single background writer thread, as are metrics files.
  One serial writer per process keeps the multi-controller barrier sequence
  inside checkpoint jobs globally ordered. ``async_io=False`` runs every
  job inline (bit-identical output either way — the writer is drained
  before `run_loop` returns, and any writer exception re-raises on the
  loop thread).
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from repro.engine.base import EngineState, PipelineEngine


@dataclass
class LoopConfig:
    steps: int
    log_every: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    out_path: Optional[str] = None
    # constant metadata merged into the JSON metrics file (arch, optimizer...)
    out_meta: Dict[str, Any] = field(default_factory=dict)
    # run checkpoint/metrics writes on the background writer thread; flip
    # off to force every write inline on the loop thread (same bytes on
    # disk — the async/sync parity test pins this)
    async_io: bool = True


class _AsyncWriter:
    """One serial background writer: jobs run strictly in submission order.

    Serial ordering is load-bearing twice over: metrics flushes must never
    overtake the checkpoint they were batched with (a metrics file lagging
    its checkpoint forfeits the pre-resume series at merge time), and in
    multi-controller runs the checkpoint jobs contain `barrier` calls whose
    names must hit the rendezvous in the same order on every process.

    A job exception is captured and re-raised on the loop thread at the
    next submit/close — a failed checkpoint must fail the run, not
    disappear into a daemon thread.
    """

    def __init__(self) -> None:
        self._q: "queue.Queue[Optional[Callable[[], None]]]" = queue.Queue()
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._drain, name="repro-io-writer", daemon=True
        )
        self._thread.start()

    def _drain(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            if self._exc is not None:
                continue  # poisoned: drop remaining jobs, surface the error
            try:
                job()
            except BaseException as e:  # noqa: BLE001 — re-raised on loop thread
                self._exc = e

    def _check(self) -> None:
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def submit(self, job: Callable[[], None]) -> None:
        self._check()
        self._q.put(job)

    def close(self) -> None:
        """Drain everything, stop the thread, re-raise any job failure."""
        self._q.put(None)
        self._thread.join()
        self._check()


def resume_if_present(
    engine: PipelineEngine,
    state: EngineState,
    ckpt_dir: Optional[str],
    data_iter: Optional[Iterator[Dict]] = None,
) -> Tuple[EngineState, int]:
    """Replace `state` with the latest checkpoint under `ckpt_dir`, if any.

    Pass the run's `data_iter` to fast-forward it past the `start_step`
    batches the interrupted run already consumed — without this a resumed
    run replays batches 0..start_step and diverges from the uninterrupted
    fixed-seed curve it is supposed to continue.
    """
    if not ckpt_dir or not os.path.exists(os.path.join(ckpt_dir, "manifest.json")):
        return state, 0
    from repro.checkpoint import load_checkpoint

    tree, step, _ = load_checkpoint(ckpt_dir)
    if data_iter is not None:
        for _ in range(step):
            next(data_iter)
    return engine.load_state(tree), step


def _read_metrics_prefix(cfg: LoopConfig, start_step: int) -> Tuple[List[float], int]:
    """Losses for absolute steps [prev_start, start_step) from an existing
    metrics file, so a resumed run keeps the full absolute-step series.

    Returns (prefix, prefix_start); falls back to an empty prefix anchored at
    `start_step` when there is no usable file (then `steps_done` still counts
    absolute steps but the series only covers the post-resume segment).
    """
    if not (cfg.out_path and start_step and os.path.exists(cfg.out_path)):
        return [], start_step
    try:
        with open(cfg.out_path) as f:
            prev = json.load(f)
        prev_losses = list(prev.get("losses", []))
        prev_start = int(prev.get("start_step", 0))
    except (ValueError, OSError, TypeError):
        return [], start_step
    need = start_step - prev_start
    if need < 0 or len(prev_losses) < need:
        return [], start_step  # gap: the old file doesn't reach start_step
    return prev_losses[:need], prev_start


def _write_metrics(
    cfg: LoopConfig, losses: List[float], steps_done: int, start_step: int
) -> None:
    os.makedirs(os.path.dirname(cfg.out_path) or ".", exist_ok=True)
    # atomic replace: a process killed mid-write (pod loss) must never leave
    # a truncated JSON — a later resume reads this file to keep the full
    # absolute-step series
    tmp = cfg.out_path + ".tmp"
    with open(tmp, "w") as f:  # incremental: survives interruption
        # losses[i] is the loss at absolute step start_step + i; on resume the
        # caller merges the pre-resume series so this covers the whole run
        json.dump({**cfg.out_meta, "steps_done": steps_done,
                   "start_step": start_step, "losses": losses}, f)
    os.replace(tmp, cfg.out_path)


def run_loop(
    engine: PipelineEngine,
    data_iter: Iterator[Dict],
    cfg: LoopConfig,
    state: Optional[EngineState] = None,
    start_step: int = 0,
    key: Any = None,
) -> Tuple[EngineState, List[float]]:
    """Run `cfg.steps` engine steps (from `start_step` when resuming).

    Multi-controller runs drive this loop on EVERY process in lock-step:
    all processes step the engine and save checkpoints (each flushes its
    own shard files), but stdout logging and the metrics JSON are
    process-0-only — non-main processes must never race on the metrics
    file the main process owns. The background writer exists on every
    process (checkpoint jobs carry barriers), metrics jobs only on main.
    """
    from repro.launch.distributed import is_main

    main = is_main()
    if state is None:
        state = engine.init_state(key=key)
    prefix, prefix_start = (
        _read_metrics_prefix(cfg, start_step) if main else ([], start_step)
    )
    losses: List[float] = []  # host floats, filled at flush cadence
    pending: List[Any] = []  # device scalars not yet fetched

    def flush_losses() -> None:
        # one deferred host sync for the whole pending window — float()
        # blocks on the newest value, by which time the rest are ready
        losses.extend(float(x) for x in pending)
        pending.clear()

    writer = _AsyncWriter() if cfg.async_io else None

    def submit(job: Callable[[], None]) -> None:
        if writer is None:
            job()
        else:
            writer.submit(job)

    try:
        t0 = time.time()
        for t in range(start_step, cfg.steps):
            batch = next(data_iter)
            state, loss, metrics = engine.step(state, batch, t)
            pending.append(loss)
            if main and cfg.log_every and t % cfg.log_every == 0:
                flush_losses()
                extra = (
                    f"  ce {float(metrics['ce']):.4f}" if "ce" in metrics else ""
                )
                print(f"step {t:5d}  loss {losses[-1]:.4f}{extra}"
                      f"  ({time.time() - t0:.1f}s)")
            wrote_ckpt = (
                cfg.ckpt_dir and cfg.ckpt_every and (t + 1) % cfg.ckpt_every == 0
            )
            if wrote_ckpt:
                # the engine owns the on-disk format (SpmdEngine writes one
                # arrays file per stage shard instead of gathering to host,
                # and in multi-process runs each process writes only its own
                # shards); the snapshot half runs here, the write half on
                # the writer thread
                submit(engine.checkpoint_job(cfg.ckpt_dir, state, step=t + 1))
            # metrics are flushed at every checkpoint too, so the metrics
            # file never lags a checkpoint a later resume will restart from
            # (a lagging file would forfeit its pre-resume series at merge
            # time; the serial writer preserves ckpt-then-metrics order)
            if main and cfg.out_path and (
                wrote_ckpt or (t + 1) % max(cfg.log_every, 1) == 0
            ):
                flush_losses()
                snapshot = prefix + losses
                done = t + 1
                submit(lambda s=snapshot, d=done: _write_metrics(
                    cfg, s, d, prefix_start
                ))
        flush_losses()
        if cfg.ckpt_dir:
            submit(engine.checkpoint_job(cfg.ckpt_dir, state, step=cfg.steps))
        if main and cfg.out_path:
            final = prefix + losses
            submit(lambda: _write_metrics(cfg, final, cfg.steps, prefix_start))
    finally:
        if writer is not None:
            writer.close()
    return state, losses
