"""The single training loop shared by every driver.

Previously the history / stale-params bookkeeping, checkpoint-resume,
incremental-JSON metrics and logging lived in near-identical copies inside
`launch/train.py`, `pipeline/simulate.py`, the benchmarks and the examples.
They now live here once, driving any `PipelineEngine` backend.

    engine = SimEngine(cfg, opt, ...)            # or SpmdEngine(...)
    state, losses = run_loop(engine, data_iter, LoopConfig(steps=300))

Checkpoint layout is unchanged from the pre-engine driver ((params,
opt_state) + step in the manifest), so old checkpoints resume under the loop.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.engine.base import EngineState, PipelineEngine


@dataclass
class LoopConfig:
    steps: int
    log_every: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    out_path: Optional[str] = None
    # constant metadata merged into the JSON metrics file (arch, optimizer...)
    out_meta: Dict[str, Any] = field(default_factory=dict)


def resume_if_present(
    engine: PipelineEngine, state: EngineState, ckpt_dir: Optional[str]
) -> Tuple[EngineState, int]:
    """Replace `state` with the latest checkpoint under `ckpt_dir`, if any."""
    if not ckpt_dir or not os.path.exists(os.path.join(ckpt_dir, "manifest.json")):
        return state, 0
    from repro.checkpoint import load_checkpoint

    tree, step, _ = load_checkpoint(ckpt_dir)
    return engine.load_state(tree), step


def _write_metrics(
    cfg: LoopConfig, losses: List[float], steps_done: int, start_step: int
) -> None:
    os.makedirs(os.path.dirname(cfg.out_path) or ".", exist_ok=True)
    with open(cfg.out_path, "w") as f:  # incremental: survives interruption
        # losses[i] is the loss at absolute step start_step + i (a resumed run
        # only holds post-resume entries)
        json.dump({**cfg.out_meta, "steps_done": steps_done,
                   "start_step": start_step, "losses": losses}, f)


def run_loop(
    engine: PipelineEngine,
    data_iter: Iterator[Dict],
    cfg: LoopConfig,
    state: Optional[EngineState] = None,
    start_step: int = 0,
    key: Any = None,
) -> Tuple[EngineState, List[float]]:
    """Run `cfg.steps` engine steps (from `start_step` when resuming)."""
    from repro.checkpoint import save_checkpoint

    if state is None:
        state = engine.init_state(key=key)
    losses: List[float] = []
    t0 = time.time()
    for t in range(start_step, cfg.steps):
        batch = next(data_iter)
        state, loss, metrics = engine.step(state, batch, t)
        losses.append(float(loss))
        if cfg.log_every and t % cfg.log_every == 0:
            extra = f"  ce {float(metrics['ce']):.4f}" if "ce" in metrics else ""
            print(f"step {t:5d}  loss {losses[-1]:.4f}{extra}"
                  f"  ({time.time() - t0:.1f}s)")
        if cfg.ckpt_dir and cfg.ckpt_every and (t + 1) % cfg.ckpt_every == 0:
            save_checkpoint(cfg.ckpt_dir, engine.checkpoint_tree(state), step=t + 1)
        if cfg.out_path and (t + 1) % max(cfg.log_every, 1) == 0:
            _write_metrics(cfg, losses, t + 1, start_step)
    if cfg.ckpt_dir:
        save_checkpoint(cfg.ckpt_dir, engine.checkpoint_tree(state), step=cfg.steps)
    if cfg.out_path:
        _write_metrics(cfg, losses, cfg.steps, start_step)
    return state, losses
