"""Simulation backend: the paper's virtual-stage setup behind the engine API.

Wraps `repro.pipeline.simulate.make_sim_train_step` — stash (delay-FIFO),
weight-prediction (PipeMare) and no-stash (two-version gradient) modes — and
owns the no-stash stale-snapshot history that used to be duplicated verbatim
in `launch/train.py` and `run_sim_training`.

The step sequence is numerically identical to the pre-engine
`run_sim_training`: same jitted step function, same call order, same history
window — fixed-seed loss curves reproduce bit-for-bit.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.engine.base import EngineState, PipelineEngine
from repro.optim.base import Optimizer


class SimEngine(PipelineEngine):
    name = "sim"

    def __init__(
        self,
        cfg: ModelConfig,
        optimizer: Optimizer,
        grad_clip: float = 1.0,
        weight_prediction: bool = False,
        delays_tree: Any = None,
        schedule: Any = None,
        no_stash: bool = False,
    ):
        from repro.pipeline.simulate import make_sim_train_step

        self.cfg = cfg
        self.optimizer = optimizer
        self.delays_tree = delays_tree
        self.no_stash = no_stash
        self._step_fn = make_sim_train_step(
            cfg, optimizer, grad_clip, weight_prediction, delays_tree,
            schedule, no_stash,
        )
        self.max_age = 0
        if no_stash and delays_tree is not None:
            self.max_age = max(
                int(d) for d in jax.tree_util.tree_leaves(delays_tree)
            )

    def init_state(self, params: Any = None, key: Any = None) -> EngineState:
        if params is None:
            from repro.models.model import init_model

            params = init_model(key if key is not None else jax.random.PRNGKey(0),
                                self.cfg)
        return EngineState(params=params, opt_state=self.optimizer.init(params))

    def step(
        self, state: EngineState, batch: Dict, t: int
    ) -> Tuple[EngineState, Any, Dict]:
        from repro.pipeline.simulate import stale_forward_params

        fwd_hist = (
            stale_forward_params(state.history, state.params, self.delays_tree)
            if self.no_stash
            else 0
        )
        params, opt_state, loss, metrics = self._step_fn(
            state.params, state.opt_state, fwd_hist, batch, jnp.int32(t)
        )
        history = state.history
        if self.no_stash and self.max_age:
            history = (history + [params])[-(self.max_age + 1):]
        return EngineState(params, opt_state, history), loss, metrics
