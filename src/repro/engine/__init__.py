"""Unified pipeline-training engine: one loop, pluggable backends.

`SimEngine` runs the paper's deterministic virtual-stage simulation on one
device; `SpmdEngine` runs the shard_map pipeline runtime with physical
staleness under a pluggable tick schedule (`engine.schedules`: fill-drain or
1F1B). Both sit behind `PipelineEngine` and are driven by
`engine.loop.run_loop` (see DESIGN.md §2-3).
"""
from repro.engine.base import EngineState, PipelineEngine
from repro.engine.loop import LoopConfig, resume_if_present, run_loop
from repro.engine.schedules import (
    SCHEDULES,
    make_1f1b_grad,
    make_fill_drain_loss,
    make_schedule_grad,
    schedule_activation_bytes,
)
from repro.engine.sim import SimEngine
from repro.engine.spmd import (
    SpmdEngine,
    make_pipeline_grad,
    make_pipeline_loss,
    spmd_delay_specs,
    stack_stage_params,
    unstack_stage_params,
)

__all__ = [
    "EngineState",
    "PipelineEngine",
    "LoopConfig",
    "resume_if_present",
    "run_loop",
    "SCHEDULES",
    "SimEngine",
    "SpmdEngine",
    "make_1f1b_grad",
    "make_fill_drain_loss",
    "make_pipeline_grad",
    "make_pipeline_loss",
    "make_schedule_grad",
    "schedule_activation_bytes",
    "spmd_delay_specs",
    "stack_stage_params",
    "unstack_stage_params",
]
