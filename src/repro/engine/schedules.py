"""Pipeline tick schedules for the SPMD runtime (DESIGN.md §3).

One schedule = one way to order forward/backward work over the `stage` mesh
axis inside a single jitted program. Both schedules share the same contract:

    grad_fn = make_schedule_grad(cfg, mesh, K, M, schedule=...)
    loss, (g_stacked, g_shared) = grad_fn(stage_params, shared, batch)

and both keep the tick body inside `jax.lax.scan`, so trace/jaxpr size is
O(1) in the microbatch count M and the stage count K.

* ``fill_drain`` (GPipe-shaped): M + K - 1 forward ticks collect every
  microbatch's output into an (M, mb, S, d) buffer; reverse-mode autodiff
  through the scanned ppermute schedule generates the backward pipeline.
  Live activation memory is **O(M)** per stage (the staged embeddings and the
  collect buffer, plus the scan residuals autodiff stashes per tick).

* ``1f1b`` (one-forward-one-backward): every tick runs at most one forward
  and one backward microbatch per stage, with activations ppermuted forward
  and activation-gradients ppermuted backward in the same tick body. The
  backward is explicit — per-tick `jax.vjp` against a stashed stage *input*
  (recompute-style, so no residuals accumulate across the scan) — and
  parameter gradients are accumulated in the carry. The stash is a circular
  buffer of 2K - 1 slots: stage k's input for microbatch m is consumed by its
  own backward exactly 2(K-1-k) ticks later, so **O(K)** live activations per
  stage, independent of M. This is the memory property production 1F1B exists
  for; the gradient itself is identical (fp32 tolerance) to fill-drain's.

1F1B tick timetable (t = 0 .. M + 2K - 3):
  forward   F(k, m) at t = k + m
  backward  B(k, m) at t = 2(K-1) - k + m
so the last stage's backward of microbatch m consumes its own fresh forward
output (same tick), and B(k, m) receives the activation gradient B(k+1, m)
sent one tick earlier. Stage warm-up/drain ticks are masked out with
`jnp.where`; `jax.vjp` is linear in the cotangent, so a zero-masked incoming
gradient yields exactly zero parameter/input gradients for idle ticks.

Staleness composes the same for both schedules: the scanned loss is
synchronous, and `stage_delayed_optimizer` imposes the per-stage delay on the
resulting gradient (DESIGN.md §3, staleness semantics).

``data_axis`` is whatever `Topology.schedule_data_axis` hands over: the bare
``"data"`` axis on single-pod meshes or the ``("pod", "data")`` tuple on
pod-replicated ones — every loss/gradient `pmean` spans the full tuple, so
multi-pod runs are combined data + pipeline parallelism, not replicated
training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm
from repro.models.model import _embed, _logits, cast_params, cross_entropy
from repro.models.transformer import block_train

SCHEDULES = ("fill_drain", "1f1b")

# Structural invariants each schedule promises, consumed by the static
# analyzer (`repro.analysis`, DESIGN.md §8). A new schedule MUST declare its
# row here — the matrix runner refuses to audit undeclared schedules:
#   const_float_bytes_in_M  largest live float buffer is O(1) in the
#                           microbatch count (1F1B's O(K) stash property;
#                           fill-drain's buffers legitimately grow with M,
#                           which the analyzer checks as non-vacuous growth)
#   vocab_dot_gated         the O(vocab) LM-head matmul inside the scanned
#                           tick body must sit under a lax.cond (and exist);
#                           schedules computing logits outside the scan set
#                           False — the analyzer still requires zero
#                           ungated vocab dots inside the scan either way
#   stash_bound             the 2K-1 input-stash bound applies
SCHEDULE_INVARIANTS = {
    "fill_drain": {
        "const_float_bytes_in_M": False,
        "vocab_dot_gated": False,
        "stash_bound": False,
    },
    "1f1b": {
        "const_float_bytes_in_M": True,
        "vocab_dot_gated": True,
        "stash_bound": True,
    },
}


def _stage_apply_fn(cfg: ModelConfig):
    """stage_f(wk_raw, x): cast the stage's stacked layers and scan them.

    The cast lives inside so `jax.vjp(stage_f, wk_raw, x)` yields gradients
    with respect to the raw fp32 master weights, exactly like autodiff
    through fill-drain's single outer cast.
    """
    spec = cfg.pattern[0]

    def stage_f(wk_raw, x):
        wk = cast_params(wk_raw, cfg.compute_dtype)

        def body(h, w):
            h, _ = block_train(w, h, cfg, spec)
            return h, None

        x, _ = jax.lax.scan(body, x, wk)
        return x

    return stage_f


def _embed_fn(cfg: ModelConfig):
    def embed_f(shared_raw, tokens_m):
        sh = cast_params(shared_raw, cfg.compute_dtype)
        emb = _embed(sh, cfg, tokens_m)  # (mb, S, d)
        if cfg.learnable_pos_emb:
            emb = emb + sh["pos_emb"][: tokens_m.shape[-1]].astype(emb.dtype)
        return emb

    return embed_f


def _head_fn(cfg: ModelConfig):
    def head_f(shared_raw, h, labels_m):
        sh = cast_params(shared_raw, cfg.compute_dtype)
        x = apply_norm(sh["final_norm"], h)
        logits = _logits(sh, cfg, x)  # (mb, S, V)
        return cross_entropy(logits, labels_m)

    return head_f


# ---------------------------------------------------------------------------
# fill-drain: scanned forward schedule, backward via autodiff
# ---------------------------------------------------------------------------


def make_fill_drain_loss(
    cfg: ModelConfig,
    mesh: Mesh,
    num_stages: int,
    num_microbatches: int,
    stage_axis: str = "stage",
    data_axis: str = "data",
):
    """Returns loss_fn(stage_params, shared_params, batch) -> scalar.

    batch: tokens/labels of shape (M, mb, S) sharded over data on dim 1.
    """
    M = num_microbatches
    stage_f = _stage_apply_fn(cfg)

    def per_device(stage_params, shared, tokens, labels):
        # stage_params leaves arrive as (1, per, ...) local slices
        wk_raw = jax.tree.map(lambda x: x[0], stage_params)
        shared_c = cast_params(shared, cfg.compute_dtype)
        k = jax.lax.axis_index(stage_axis)
        K = num_stages
        mb, S = tokens.shape[1], tokens.shape[2]

        emb = _embed(shared_c, cfg, tokens)  # (M, mb, S, d)
        if cfg.learnable_pos_emb:
            emb = emb + shared_c["pos_emb"][:S].astype(emb.dtype)

        d = emb.shape[-1]
        zeros = jnp.zeros((mb, S, d), emb.dtype)
        out_buf = jnp.zeros((M, mb, S, d), emb.dtype)
        fwd_perm = [(i, i + 1) for i in range(K - 1)]

        # Fill-drain schedule as a scan over ticks: stage 0 injects microbatch
        # t while t < M, the last stage collects microbatch t - (K-1) once it
        # exists. The tick body is traced ONCE — trace/jaxpr size is constant
        # in M and K (the Python-unrolled predecessor was O(M + K)).
        def tick(carry, t):
            recv, out = carry
            inject = jax.lax.dynamic_index_in_dim(
                emb, jnp.minimum(t, M - 1), axis=0, keepdims=False
            )
            inject = jnp.where(t < M, inject, zeros)
            inp = jnp.where(k == 0, inject, recv)
            h = stage_f(wk_raw, inp)
            mb_idx = t - (K - 1)
            collect = (mb_idx >= 0) & (k == K - 1)
            idx = jnp.clip(mb_idx, 0, M - 1)
            cur = jax.lax.dynamic_index_in_dim(out, idx, axis=0, keepdims=False)
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where(collect, h, cur), idx, axis=0
            )
            recv = jax.lax.ppermute(h, stage_axis, fwd_perm)
            return (recv, out), None

        ticks = jnp.arange(M + K - 1)
        (_, out_buf), _ = jax.lax.scan(tick, (zeros, out_buf), ticks)

        x = apply_norm(shared_c["final_norm"], out_buf)
        logits = _logits(shared_c, cfg, x)  # (M, mb, S, V)
        ce = cross_entropy(logits, labels)
        is_last = (k == K - 1).astype(jnp.float32)
        # only the last stage's loss is real; psum over stages, mean over the
        # data axes (a tuple covers the multi-pod (pod, data) case)
        loss = jax.lax.psum(ce * is_last, stage_axis)
        loss = jax.lax.pmean(loss, data_axis)
        return loss

    from jax.experimental.shard_map import shard_map

    ln = shard_map(
        per_device,
        mesh=mesh,
        in_specs=(
            P(stage_axis),  # stage params stacked on stage axis
            P(),  # shared params replicated
            P(None, data_axis, None),  # tokens (M, mb, S)
            P(None, data_axis, None),
        ),  # data_axis may be a tuple of mesh axes (multi-pod)
        out_specs=P(),
        check_rep=False,
    )

    def loss_fn(stage_params, shared, batch):
        return ln(stage_params, shared, batch["tokens"], batch["labels"])

    return loss_fn


def make_fill_drain_local_grad(
    cfg: ModelConfig,
    mesh: Mesh,
    num_stages: int,
    num_microbatches: int,
    stage_axis: str = "stage",
    data_axis: str = "data",
):
    """Fill-drain gradient WITHOUT the data-axis reduction (async data mode).

    Returns ``grad_fn(stage_params, shared, batch) ->
    (loss_r, (gs_r, gsh_r))`` where the leading axis of every output is the
    data replica: loss_r ``(R,)``, gs_r ``(R, K, per, ...)``, gsh_r
    ``(R, ...)`` for R = product of the data axes. No collective over the
    data axes appears anywhere in the program — the deferred cross-replica
    mean runs in a separate reduce program off the step critical path.

    The synchronous fill-drain path differentiates OUTSIDE shard_map, where
    the transpose of the replicated-parameter broadcast IS the data-axis
    psum; here `jax.value_and_grad` runs INSIDE the per-device body (of the
    per-device masked loss — the stage psums happen after differentiation),
    so autodiff transposes only the ppermute chain and each replica keeps
    its own local gradient.
    """
    M = num_microbatches
    stage_f = _stage_apply_fn(cfg)

    def per_device(stage_params, shared, tokens, labels):
        k = jax.lax.axis_index(stage_axis)
        K = num_stages
        mb, S = tokens.shape[1], tokens.shape[2]

        def local_loss(stage_params, shared):
            # identical tick schedule to make_fill_drain_loss, minus the
            # final pmean over the data axes
            wk_raw = jax.tree.map(lambda x: x[0], stage_params)
            shared_c = cast_params(shared, cfg.compute_dtype)

            emb = _embed(shared_c, cfg, tokens)  # (M, mb, S, d)
            if cfg.learnable_pos_emb:
                emb = emb + shared_c["pos_emb"][:S].astype(emb.dtype)

            d = emb.shape[-1]
            zeros = jnp.zeros((mb, S, d), emb.dtype)
            out_buf = jnp.zeros((M, mb, S, d), emb.dtype)
            fwd_perm = [(i, i + 1) for i in range(K - 1)]

            def tick(carry, t):
                recv, out = carry
                inject = jax.lax.dynamic_index_in_dim(
                    emb, jnp.minimum(t, M - 1), axis=0, keepdims=False
                )
                inject = jnp.where(t < M, inject, zeros)
                inp = jnp.where(k == 0, inject, recv)
                h = stage_f(wk_raw, inp)
                mb_idx = t - (K - 1)
                collect = (mb_idx >= 0) & (k == K - 1)
                idx = jnp.clip(mb_idx, 0, M - 1)
                cur = jax.lax.dynamic_index_in_dim(
                    out, idx, axis=0, keepdims=False
                )
                out = jax.lax.dynamic_update_index_in_dim(
                    out, jnp.where(collect, h, cur), idx, axis=0
                )
                recv = jax.lax.ppermute(h, stage_axis, fwd_perm)
                return (recv, out), None

            ticks = jnp.arange(M + K - 1)
            (_, out_buf), _ = jax.lax.scan(tick, (zeros, out_buf), ticks)

            x = apply_norm(shared_c["final_norm"], out_buf)
            logits = _logits(shared_c, cfg, x)  # (M, mb, S, V)
            ce = cross_entropy(logits, labels)
            is_last = (k == K - 1).astype(jnp.float32)
            # per-device masked loss, NOT stage-psum'd: psum transposes to
            # psum, so differentiating through an in-body stage psum would
            # seed the cotangent K times (once per stage) and scale every
            # gradient by K. The masked scalar seeds only the last stage's
            # ce; transposed ppermutes carry its cotangent back through the
            # pipeline, exactly like the outer-autodiff sync path.
            return ce * is_last

        loss, (gs, gsh) = jax.value_and_grad(local_loss, argnums=(0, 1))(
            stage_params, shared
        )
        # replicate the loss value across stages AFTER differentiation (the
        # transpose never sees these psums); still no data-axis collective.
        # Shared grads are also summed over stages: each stage holds only its
        # own contribution (embed on stage 0, norm/head on the last stage)
        # and the P(data_axis) out_spec requires stage-replicated values —
        # same tail as the 1f1b unreduced path.
        loss = jax.lax.psum(loss, stage_axis)
        gsh = jax.tree.map(lambda a: jax.lax.psum(a, stage_axis), gsh)
        # add the replica axis: per-device shapes (1,), (1, 1, per, ...),
        # (1, ...) assemble to (R,), (R, K, per, ...), (R, ...) globally
        return (
            loss[None],
            jax.tree.map(lambda a: a[None], gs),
            jax.tree.map(lambda a: a[None], gsh),
        )

    from jax.experimental.shard_map import shard_map

    gf = shard_map(
        per_device,
        mesh=mesh,
        in_specs=(
            P(stage_axis),
            P(),
            P(None, data_axis, None),
            P(None, data_axis, None),
        ),
        out_specs=(
            P(data_axis),
            P(data_axis, stage_axis),
            P(data_axis),
        ),
        check_rep=False,
    )

    def grad_fn(stage_params, shared, batch):
        loss_r, gs_r, gsh_r = gf(
            stage_params, shared, batch["tokens"], batch["labels"]
        )
        return loss_r, (gs_r, gsh_r)

    return grad_fn


# ---------------------------------------------------------------------------
# 1F1B: explicit forward/backward ticks, O(K) activation stash
# ---------------------------------------------------------------------------


def stash_slots(num_stages: int) -> int:
    """Circular-buffer depth of the 1F1B input stash.

    Stage k re-reads its forward input 2(K-1-k) ticks later; the worst case
    (stage 0) is 2(K-1), so 2K - 1 slots suffice for every stage and a slot
    is only overwritten after its consumer has read it. The static analyzer
    enforces this as the ``stash_bound`` check: no activation-shaped buffer
    in the traced step may exceed this depth.
    """
    return 2 * num_stages - 1


_stash_slots = stash_slots  # pre-analysis-layer private name


def make_1f1b_grad(
    cfg: ModelConfig,
    mesh: Mesh,
    num_stages: int,
    num_microbatches: int,
    stage_axis: str = "stage",
    data_axis: str = "data",
    reduce_data: bool = True,
):
    """Returns grad_fn(stage_params, shared, batch) -> (loss, (gs, gsh)).

    Explicit-backward 1F1B: no reverse-mode pass over the tick scan, so XLA
    never materialises an O(M) residual/output buffer — the only per-stage
    activation state is the (2K-1, mb, S, d) input stash in the carry.

    ``reduce_data=False`` (async data mode) skips the three data-axis pmeans
    and returns per-replica outputs with a leading replica axis — loss
    ``(R,)``, gs ``(R, K, per, ...)``, gsh ``(R, ...)`` — leaving NO
    collective over the data axes in the program; the deferred cross-replica
    mean runs in a separate reduce program off the step critical path.
    """
    M = num_microbatches
    K = num_stages
    Q = stash_slots(K)
    stage_f = _stage_apply_fn(cfg)
    embed_f = _embed_fn(cfg)
    head_f = _head_fn(cfg)

    def per_device(stage_params, shared, tokens, labels):
        wk_raw = jax.tree.map(lambda x: x[0], stage_params)
        k = jax.lax.axis_index(stage_axis)
        mb, S = tokens.shape[1], tokens.shape[2]
        d = cfg.d_model
        cdt = cfg.compute_dtype
        zeros = jnp.zeros((mb, S, d), cdt)
        fwd_perm = [(i, i + 1) for i in range(K - 1)]
        bwd_perm = [(i + 1, i) for i in range(K - 1)]

        g_stage0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), wk_raw)
        g_shared0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), shared)
        stash0 = jnp.zeros((Q, mb, S, d), cdt)

        def tick(carry, t):
            fwd_recv, bwd_recv, stash, g_stage, g_shared, loss_acc = carry

            # -- forward: stage k runs microbatch m_f = t - k ----------------
            m_f = t - k
            do_f = (m_f >= 0) & (m_f < M)
            idx_f = jnp.clip(m_f, 0, M - 1)
            tok_f = jax.lax.dynamic_index_in_dim(tokens, idx_f, 0, keepdims=False)
            x_in = jnp.where(k == 0, embed_f(shared, tok_f), fwd_recv)
            x_in = jnp.where(do_f, x_in, zeros)  # idle ticks stash zeros
            h = stage_f(wk_raw, x_in)
            stash = jax.lax.dynamic_update_index_in_dim(stash, x_in, t % Q, 0)

            # -- backward: stage k runs microbatch m_b = t - (2(K-1) - k) ----
            m_b = t - (2 * (K - 1) - k)
            do_b = (m_b >= 0) & (m_b < M)
            idx_b = jnp.clip(m_b, 0, M - 1)
            lbl_b = jax.lax.dynamic_index_in_dim(labels, idx_b, 0, keepdims=False)
            tok_b = jax.lax.dynamic_index_in_dim(tokens, idx_b, 0, keepdims=False)

            # the last stage seeds its backward from this tick's fresh output
            # (m_b == m_f there); every microbatch contributes ce_m / M, which
            # equals fill-drain's joint mean when microbatches are full. The
            # O(vocab) head matmul + its vjp run under a lax.cond so only the
            # last stage pays for them (shard_map stages the body per device,
            # so the cond lowers to a real branch, not a masked select); the
            # other stages' head contributions were zero-masked anyway.
            def head_grads(_):
                ce, head_vjp = jax.vjp(
                    lambda sh, hh: head_f(sh, hh, lbl_b), shared, h
                )
                dsh_head, dh = head_vjp(jnp.float32(1.0 / M))
                return ce, dsh_head, dh

            def head_zeros(_):
                return (
                    jnp.float32(0.0),
                    jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), shared),
                    jnp.zeros(h.shape, h.dtype),
                )

            ce, dsh_head, dh = jax.lax.cond(
                k == K - 1, head_grads, head_zeros, 0
            )
            dy = jnp.where(k == K - 1, dh.astype(cdt), bwd_recv)
            dy = jnp.where(do_b, dy, zeros)

            # recompute-backward at the stashed input: vjp is linear in dy,
            # so masked (zero) ticks contribute exactly zero grads
            x_saved = jax.lax.dynamic_index_in_dim(
                stash, (t - 2 * (K - 1 - k)) % Q, 0, keepdims=False
            )
            _, stage_vjp = jax.vjp(stage_f, wk_raw, x_saved)
            dwk, dx = stage_vjp(dy)
            # stage 0's input grad is the embedding grad (dx is zero-masked)
            (dsh_emb,) = jax.vjp(lambda sh: embed_f(sh, tok_b), shared)[1](dx)

            head_on = (do_b & (k == K - 1)).astype(jnp.float32)
            emb_on = (k == 0).astype(jnp.float32)
            g_stage = jax.tree.map(lambda a, b: a + b, g_stage, dwk)
            g_shared = jax.tree.map(
                lambda a, hh, ee: a + head_on * hh + emb_on * ee,
                g_shared, dsh_head, dsh_emb,
            )
            loss_acc = loss_acc + head_on * ce / M

            fwd_recv = jax.lax.ppermute(h, stage_axis, fwd_perm)
            bwd_recv = jax.lax.ppermute(dx, stage_axis, bwd_perm)
            return (fwd_recv, bwd_recv, stash, g_stage, g_shared, loss_acc), None

        ticks = jnp.arange(M + 2 * (K - 1))
        carry0 = (zeros, zeros, stash0, g_stage0, g_shared0, jnp.float32(0.0))
        (_, _, _, g_stage, g_shared, loss_acc), _ = jax.lax.scan(
            tick, carry0, ticks
        )

        # loss lives on the last stage; grads follow fill-drain's reduction
        # semantics: mean over data replicas, shared grads summed over stages
        if reduce_data:
            loss = jax.lax.pmean(jax.lax.psum(loss_acc, stage_axis), data_axis)
            g_stage = jax.lax.pmean(g_stage, data_axis)
            g_shared = jax.lax.pmean(
                jax.lax.psum(g_shared, stage_axis), data_axis
            )
            g_stage = jax.tree.map(lambda a: a[None], g_stage)  # (1, per, ...)
            return loss, g_stage, g_shared
        # async data mode: stage collectives only, plus a leading replica
        # axis so each replica's local gradient survives to the output
        loss = jax.lax.psum(loss_acc, stage_axis)
        g_shared = jax.lax.psum(g_shared, stage_axis)
        g_stage = jax.tree.map(lambda a: a[None, None], g_stage)
        g_shared = jax.tree.map(lambda a: a[None], g_shared)
        return loss[None], g_stage, g_shared

    from jax.experimental.shard_map import shard_map

    out_specs = (
        (P(), P(stage_axis), P()) if reduce_data
        else (P(data_axis), P(data_axis, stage_axis), P(data_axis))
    )
    gf = shard_map(
        per_device,
        mesh=mesh,
        in_specs=(
            P(stage_axis),
            P(),
            P(None, data_axis, None),
            P(None, data_axis, None),
        ),
        out_specs=out_specs,
        check_rep=False,
    )

    def grad_fn(stage_params, shared, batch):
        loss, gs, gsh = gf(stage_params, shared, batch["tokens"], batch["labels"])
        return loss, (gs, gsh)

    return grad_fn


# ---------------------------------------------------------------------------
# Dispatch + memory model
# ---------------------------------------------------------------------------


def make_schedule_grad(
    cfg: ModelConfig,
    mesh: Mesh,
    num_stages: int,
    num_microbatches: int,
    schedule: str = "fill_drain",
    reduce_data: bool = True,
    **kw,
):
    """grad_fn(stage_params, shared, batch) -> (loss, (g_stacked, g_shared)).

    ``reduce_data=False`` returns the UNREDUCED per-replica gradient instead
    — ``(loss_r, (gs_r, gsh_r))`` with a leading data-replica axis and no
    collective over the data axes anywhere in the program (async data mode;
    the deferred cross-replica mean is a separate program).
    """
    if schedule == "fill_drain":
        if not reduce_data:
            return make_fill_drain_local_grad(
                cfg, mesh, num_stages, num_microbatches, **kw
            )
        loss_fn = make_fill_drain_loss(cfg, mesh, num_stages, num_microbatches, **kw)

        def grad_fn(stage_params, shared, batch):
            return jax.value_and_grad(loss_fn, argnums=(0, 1))(
                stage_params, shared, batch
            )

        return grad_fn
    if schedule == "1f1b":
        return make_1f1b_grad(
            cfg, mesh, num_stages, num_microbatches,
            reduce_data=reduce_data, **kw,
        )
    raise ValueError(f"unknown pipeline schedule {schedule!r}; one of {SCHEDULES}")


def schedule_activation_bytes(
    cfg: ModelConfig,
    num_stages: int,
    num_microbatches: int,
    microbatch_size: int,
    seq_len: int,
    schedule: str = "fill_drain",
) -> int:
    """Per-device live activation-buffer bytes held across schedule ticks.

    Counts the (mb, S, d)-shaped buffers a stage keeps alive between ticks —
    the quantity 1F1B bounds at O(K) while fill-drain grows it O(M):

    * fill_drain: staged embeddings (M) + output collect buffer (M) + the
      ppermute recv slot -> (2M + 1) activations.
    * 1f1b: input stash (2K - 1) + forward recv + backward recv
      -> (2K + 1) activations.
    """
    act = (
        microbatch_size * seq_len * cfg.d_model
        * jnp.dtype(cfg.compute_dtype).itemsize
    )
    if schedule == "fill_drain":
        return (2 * num_microbatches + 1) * act
    if schedule == "1f1b":
        return (stash_slots(num_stages) + 2) * act
    raise ValueError(f"unknown pipeline schedule {schedule!r}; one of {SCHEDULES}")
