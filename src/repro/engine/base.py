"""The `PipelineEngine` abstraction: one async-pipeline train-step contract,
two interchangeable backends.

* `SimEngine` (engine.sim) — the paper's virtual-stage simulation: compute is
  one jitted single-device program, staleness is imposed exactly by the
  per-leaf gradient FIFO (and, for no-stash mode, by stale forward snapshots).
* `SpmdEngine` (engine.spmd) — the distributed runtime: a `shard_map` over a
  `stage` mesh axis moves activations with ppermute, autodiff generates the
  backward pipeline, and the same delay-FIFO wrapper applies PipeDream
  weight-stashing staleness to the stage-sharded parameters.

Both expose the same surface, so the single loop in `engine.loop` drives
either backend (launch driver, benchmarks, examples, tests):

    state = engine.init_state(params=..., key=...)
    state, loss, metrics = engine.step(state, batch, t)

`EngineState` is deliberately a plain container: `params` and `opt_state` are
backend-specific pytrees (SPMD keeps the stage-stacked representation), and
`history` is the sim backend's no-stash snapshot window. `checkpoint_tree` /
`load_state` convert to/from the backend-agnostic `(params, opt_state)`
payload the checkpointer stores.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclass
class EngineState:
    params: Any
    opt_state: Any
    history: List[Any] = field(default_factory=list)
    # async data axis (SpmdEngine ``data_async``): FIFO of the last D
    # deferred cross-replica gradient reductions, oldest first. ``None``
    # whenever the data axis is synchronous.
    data_fifo: Optional[List[Any]] = None


class PipelineEngine(abc.ABC):
    """One asynchronous pipeline-parallel training runtime."""

    name: str = "engine"

    @abc.abstractmethod
    def init_state(self, params: Any = None, key: Any = None) -> EngineState:
        """Build the initial state (init the model when `params` is None)."""

    @abc.abstractmethod
    def step(
        self, state: EngineState, batch: Dict, t: int
    ) -> Tuple[EngineState, Any, Dict]:
        """One optimizer step. Returns (new_state, loss, metrics)."""

    # -- checkpointing -----------------------------------------------------

    def checkpoint_tree(self, state: EngineState) -> Any:
        """Backend-specific pytree handed to `save_checkpoint`."""
        return (state.params, state.opt_state)

    def checkpoint_job(
        self, path: str, state: EngineState, step: int = 0,
        meta: Optional[Dict] = None,
    ) -> Callable[[], None]:
        """Snapshot `state` to host NOW; return the deferred write.

        The split is what makes donated train steps and async checkpointing
        compose: the snapshot (cheap device->host copies) runs on the loop
        thread before the next step is dispatched — afterwards the donated
        buffers may be reused/deleted — while the returned closure does only
        host-side file I/O and may run on a background writer thread
        (engine.loop submits it there under `LoopConfig.async_io`).

        The default snapshot is `jax.device_get` of the gathered tree;
        `SpmdEngine` overrides with per-stage-shard host slices so the
        stage-sharded params/FIFO/optimizer state never gather to one host.
        """
        import jax

        host_tree = jax.device_get(self.checkpoint_tree(state))

        def write() -> None:
            from repro.checkpoint import save_checkpoint

            save_checkpoint(path, host_tree, step=step, meta=meta)

        return write

    def save_checkpoint(
        self, path: str, state: EngineState, step: int = 0,
        meta: Optional[Dict] = None,
    ) -> None:
        """Write `state` under `path`; the engine picks the on-disk format.

        Synchronous composition of `checkpoint_job` (snapshot + immediate
        write). Loading is format-agnostic
        (`repro.checkpoint.load_checkpoint`).
        """
        self.checkpoint_job(path, state, step=step, meta=meta)()

    def load_state(self, tree: Any) -> EngineState:
        """Rebuild an `EngineState` from `checkpoint_tree` output.

        The no-stash history window is not checkpointed (matching the
        pre-engine driver): after resume the first max-delay steps fall back
        to the freshest snapshot available.
        """
        params, opt_state = tree
        return EngineState(params=params, opt_state=opt_state)
