"""The paper's ~1B model (Appendix D.2): d_model=1728, 27 heads, 24 blocks."""
from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="paper_1b",
    family="dense",
    source="paper Appendix D.2",
    num_layers=24,
    d_model=1728,
    d_ff=6912,
    vocab_size=50304,
    max_seq_len=512,
    attention=AttentionConfig(num_heads=27, num_kv_heads=27, head_dim=64),
    pattern=(BlockSpec("attn", "dense"),),
    norm="layernorm",
    mlp_act="gelu",
    learnable_pos_emb=True,
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "dense"),) * 2)
