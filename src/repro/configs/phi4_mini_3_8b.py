"""Phi-4-mini 3.8B [arXiv:2412.08905]. 32L, d_model=3072, 24 heads (GQA kv=8),
d_ff=8192, vocab=200064, RoPE + SwiGLU + GQA. Full attention -> long_500k
skipped by default; `SWA_CONFIG` is the beyond-paper sliding-window variant
(window 8192) that unlocks the 500k decode shape for a dense arch."""
from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="phi4_mini_3_8b",
    family="dense",
    source="arXiv:2412.08905",
    num_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab_size=200064,
    max_seq_len=131072,
    attention=AttentionConfig(num_heads=24, num_kv_heads=8, head_dim=128),
    pattern=(BlockSpec("attn", "dense"),),
    dtype="bfloat16",
    param_dtype="float32",
)

SWA_CONFIG = CONFIG.replace(
    name="phi4_mini_3_8b_swa",
    attention=AttentionConfig(num_heads=24, num_kv_heads=8, head_dim=128, window=8192),
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "dense"),) * 2)
