"""The paper's ~95M nanoGPT model (Appendix D.2): d_model=384, 6 heads,
32 blocks, seq 512, learnable positional embedding, untied LM head."""
from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="paper_95m",
    family="dense",
    source="paper Appendix D.2 (nanoGPT)",
    num_layers=32,
    d_model=384,
    d_ff=1536,
    vocab_size=50304,
    max_seq_len=512,
    attention=AttentionConfig(num_heads=6, num_kv_heads=6, head_dim=64),
    pattern=(BlockSpec("attn", "dense"),),
    norm="layernorm",
    mlp_act="gelu",
    learnable_pos_emb=True,
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "dense"),) * 2)
