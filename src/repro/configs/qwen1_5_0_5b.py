"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B]. 24L, d_model=1024, 16 heads
(MHA kv=16), head_dim=64, d_ff=2816, vocab=151936, QKV bias. Full attention
-> long_500k skipped."""
from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="qwen1_5_0_5b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    d_ff=2816,
    vocab_size=151936,
    max_seq_len=32768,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64, qkv_bias=True),
    pattern=(BlockSpec("attn", "dense"),),
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "dense"),) * 2)
