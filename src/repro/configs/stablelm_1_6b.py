"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b]. 24L, d_model=2048,
32 heads (MHA: kv=32), d_ff=5632, vocab=100352, LayerNorm. Full attention ->
long_500k skipped."""
from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="stablelm_1_6b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=24,
    d_model=2048,
    d_ff=5632,
    vocab_size=100352,
    max_seq_len=4096,
    attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=64),
    pattern=(BlockSpec("attn", "dense"),),
    norm="layernorm",
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "dense"),) * 2)
