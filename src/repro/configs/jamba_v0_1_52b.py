"""Jamba-v0.1 52B [arXiv:2403.19887]. 32L, d_model=4096, 32 heads (GQA kv=8),
d_ff=14336, vocab=65536, MoE 16 experts top-2. Superblock of 8 layers:
attention at position 4, Mamba elsewhere (1:7), MoE on odd positions.
Hybrid recurrent state -> long_500k runs."""
from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    MoEConfig,
    ModelConfig,
    SSMConfig,
)
from repro.configs.catalog import reduce_for_smoke

_PATTERN = tuple(
    BlockSpec(
        mixer="attn" if i == 4 else "mamba",
        mlp="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba_v0_1_52b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    max_seq_len=262144,
    attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336),
    pattern=_PATTERN,
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE_CONFIG = reduce_for_smoke(
    CONFIG,
    num_layers=2,
    pattern=(BlockSpec("mamba", "moe"), BlockSpec("attn", "dense")),
)
