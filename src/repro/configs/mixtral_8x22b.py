"""Mixtral 8x22B [arXiv:2401.04088]. 56L, d_model=6144, 48 heads (GQA kv=8),
d_ff=16384, vocab=32768, MoE 8 experts top-2, sliding-window attention
(window 4096) -> windowed cache makes long_500k feasible."""
from repro.configs.base import AttentionConfig, BlockSpec, MoEConfig, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="mixtral_8x22b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    d_ff=16384,
    vocab_size=32768,
    max_seq_len=65536,
    attention=AttentionConfig(num_heads=48, num_kv_heads=8, head_dim=128, window=4096),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
    pattern=(BlockSpec("attn", "moe"),),
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "moe"),) * 2)
