"""LLaVA-NeXT-34B language backbone [hf:llava-hf/llava-v1.6-mistral-7b-hf,
34B variant]. 60L, d_model=7168, 56 heads (GQA kv=8), d_ff=20480,
vocab=64000. AnyRes vision tiling is STUBBED: `input_specs` supplies
precomputed patch embeddings (frontend_dim=1152, SigLIP-patch-sized) and the
model owns only the projector into d_model. Full attention -> long_500k is
skipped (DESIGN.md §6)."""
from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="llava_next_34b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34B cfg)",
    num_layers=60,
    d_model=7168,
    d_ff=20480,
    vocab_size=64000,
    max_seq_len=32768,
    attention=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128),
    pattern=(BlockSpec("attn", "dense"),),
    frontend="vision",
    frontend_tokens=2880,  # anyres: base 576 + 4 tiles x 576
    frontend_dim=1152,
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "dense"),) * 2)
