"""The paper's ~3B model (Appendix I): d_model=2688, 32 blocks."""
from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="paper_3b",
    family="dense",
    source="paper Appendix I (Fig. 20)",
    num_layers=32,
    d_model=2688,
    d_ff=10752,
    vocab_size=50304,
    max_seq_len=512,
    attention=AttentionConfig(num_heads=42, num_kv_heads=42, head_dim=64),
    pattern=(BlockSpec("attn", "dense"),),
    norm="layernorm",
    mlp_act="gelu",
    learnable_pos_emb=True,
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "dense"),) * 2)
