"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family]. 28L, d_model=1024, 16 heads
(GQA kv=8), head_dim=128 (q-dim 2048 != d_model), d_ff=3072, vocab=151936,
qk-norm. Full attention -> long_500k skipped."""
from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="qwen3_0_6b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (0.6B cfg)",
    num_layers=28,
    d_model=1024,
    d_ff=3072,
    vocab_size=151936,
    max_seq_len=32768,
    attention=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=128, qk_norm=True),
    pattern=(BlockSpec("attn", "dense"),),
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "dense"),) * 2)
