"""DeepSeek-V2 236B [arXiv:2405.04434]. 60L, d_model=5120, 128 heads, MLA with
kv_lora_rank=512 (q_lora_rank=1536, nope=128, rope=64, v=128), per-expert
d_ff=1536, vocab=102400, MoE: 2 shared + 160 routed top-6. The MLA compressed
cache (512+64 per token) makes the 500k decode shape run (DESIGN.md §6)."""
from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    MoEConfig,
    ModelConfig,
)
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="deepseek_v2_236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    d_ff=1536,
    vocab_size=102400,
    max_seq_len=131072,
    attention=AttentionConfig(
        kind="mla",
        num_heads=128,
        num_kv_heads=128,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(num_experts=160, top_k=6, num_shared=2, d_ff_expert=1536),
    pattern=(BlockSpec("attn", "moe"),),
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE_CONFIG = reduce_for_smoke(CONFIG, num_layers=2, pattern=(BlockSpec("attn", "moe"),) * 2)
