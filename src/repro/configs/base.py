"""Configuration dataclasses for models, input shapes and training.

Every assigned architecture gets a module in ``repro.configs`` exporting
``CONFIG`` (full size, exercised only through the AOT dry-run) and
``SMOKE_CONFIG`` (reduced: <=2 superblocks, d_model<=512, <=4 experts) that is
actually instantiated and stepped on CPU by the test-suite.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Attention / mixer / MLP configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    """Attention mixer configuration (GQA or MLA)."""

    kind: str = "gqa"  # "gqa" | "mla"
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None  # sliding-window size (None = full causal)
    rope_theta: float = 10_000.0
    # MLA-only fields (DeepSeek-V2 style latent attention)
    q_lora_rank: int = 0  # 0 = no query compression
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.num_heads * self.head_dim

    @property
    def kv_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent mixer configuration."""

    kind: str = "mamba"  # "mamba" | "mlstm" | "slstm"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    num_heads: int = 4  # for m/sLSTM
    proj_factor: float = 2.0  # mLSTM up-projection factor


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts MLP configuration."""

    num_experts: int = 8
    top_k: int = 2
    num_shared: int = 0  # DeepSeek-style always-on shared experts
    d_ff_expert: int = 0  # 0 -> use model d_ff
    aux_loss_coef: float = 0.01
    router_jitter: float = 0.0
    capacity_factor: float = 1.25
    # GShard routing-group size; smaller groups shrink the dispatch one-hots
    # (per-token dispatch flops scale with capacity ~ group * top_k / E)
    group_size: int = 4096


@dataclass(frozen=True)
class BlockSpec:
    """One layer inside the repeating superblock pattern."""

    mixer: str = "attn"  # "attn" | "mamba" | "mlstm" | "slstm"
    mlp: str = "dense"  # "dense" | "moe" | "none"


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""  # citation for the configuration
    num_layers: int = 2
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 4096
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    ssm: Optional[SSMConfig] = None
    moe: Optional[MoEConfig] = None
    # Repeating layer pattern; len(pattern) must divide num_layers.
    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    mlp_act: str = "swiglu"  # "swiglu" | "gelu"
    tie_embeddings: bool = False
    learnable_pos_emb: bool = False  # paper's nanoGPT models use this
    # Modality frontend stub: embeddings are provided by input_specs().
    frontend: Optional[str] = None  # None | "vision" | "audio"
    frontend_tokens: int = 0  # number of prefix embedding tokens (vlm)
    frontend_dim: int = 0  # raw embedding dim fed to the projector
    num_codebooks: int = 1  # >1 => musicgen-style multi-codebook heads
    # True: stack superblocks and lax.scan (compact HLO for deep dry-runs).
    # False: one param subtree per layer (per-layer delay/freq in simulation).
    scan_layers: bool = True
    # Unroll the superblock scan (dry-run only): XLA cost_analysis counts a
    # while-loop body ONCE, so rooflines need straight-line HLO.
    scan_unroll: bool = False
    # fp32 logits (paper default, CE stability) vs bf16 (saves the dominant
    # temp buffer at 1M-token batches; CE still reduces in fp32)
    logits_fp32: bool = True
    # activation-checkpoint policy: "full" recomputes everything;
    # "dots" saves matmul outputs (less recompute FLOPs, more memory)
    remat_policy: str = "full"
    # sequence parallelism (Korthikanti et al. 2023): shard the residual's
    # sequence dim over the `model` axis between blocks, lowering the TP
    # activation all-reduces to reduce-scatter + all-gather pairs (~2x less
    # inter-chip traffic). [beyond-paper optimization]
    seq_sharded: bool = False
    # chunked cross-entropy: compute logits + CE over sequence chunks of this
    # length so the (B, S, V) logits tensor is never materialised.
    # 0 = off. [beyond-paper optimization]
    loss_chunk: int = 0
    dtype: str = "float32"  # compute dtype
    param_dtype: str = "float32"
    # route the fused Pallas attention (fwd + custom-vjp bwd) into the stage
    # apply (models/attention.py); the optimizer kernel path is routed
    # separately through optim.factory.build_optimizer
    use_kernels: bool = False

    # -- derived -----------------------------------------------------------
    @property
    def num_superblocks(self) -> int:
        assert self.num_layers % len(self.pattern) == 0, (
            f"pattern length {len(self.pattern)} must divide "
            f"num_layers {self.num_layers}"
        )
        return self.num_layers // len(self.pattern)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def supports_long_context(self) -> bool:
        """True when the 500k decode shape is admissible (DESIGN.md §6):
        recurrent/hybrid families, or attention that is windowed everywhere."""
        if self.family in ("ssm", "hybrid"):
            return True
        return all(
            spec.mixer != "attn" or self.attention.window is not None
            for spec in self.pattern
        )


# ---------------------------------------------------------------------------
# Precision policies
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PrecisionPolicy:
    """Compute/state dtype discipline applied on top of a ModelConfig.

    The layering contract (DESIGN.md §9): the policy only *selects* dtypes;
    the model's apply path owns every cast (``cast_params`` masters→compute,
    f32 softmax/CE accumulation, ``logits_fp32``), and the engine/optimizer
    never see anything but f32 state. ``bf16_compute`` = bf16 activations and
    matmuls with f32 parameter masters, optimizer state and loss reductions —
    enforced statically by ``analysis.BF16_COMPUTE_POLICY``.
    """

    name: str = "f32"
    dtype: str = "float32"  # activation / matmul compute dtype
    param_dtype: str = "float32"  # parameter masters (and optimizer state)
    logits_fp32: bool = True  # CE stability: keep the vocab head f32

    def apply(self, cfg: "ModelConfig") -> "ModelConfig":
        return cfg.replace(
            dtype=self.dtype, param_dtype=self.param_dtype,
            logits_fp32=self.logits_fp32,
        )


PRECISION_POLICIES = {
    "f32": PrecisionPolicy(),
    "bf16": PrecisionPolicy(name="bf16_compute", dtype="bfloat16"),
}


# ---------------------------------------------------------------------------
# Input shapes (assigned) and training configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "basis_rotation"  # adam | adamw | adasgd | nesterov |
    # nesterov_pp | pipedream_lr | delay_compensation | basis_rotation
    learning_rate: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    # schedule
    warmup_frac: float = 0.012
    schedule: str = "cosine"  # "cosine" | "constant"
    total_steps: int = 1000
    # basis rotation
    rotation_source: str = "2nd"  # "1st" | "2nd"
    rotation_geometry: str = "bilateral"  # "unilateral" | "bilateral"
    rotation_freq: int = 10
    stage_aware: bool = False
    stage_aware_reversed: bool = False  # ablation (Fig. 17)
    # delay compensation
    dc_lambda: float = 0.1
    # nesterov (Ajanthan et al. use beta1=0.99)
    nesterov_beta: float = 0.99


@dataclass(frozen=True)
class PipelineConfig:
    num_stages: int = 1
    num_microbatches: int = 1
    weight_stashing: bool = True
    weight_prediction: bool = False  # PipeMare-style
    schedule: str = "async"  # "sync" (GPipe) | "async" (PipeDream 1F1B)


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    pipeline: PipelineConfig = field(default_factory=PipelineConfig)
    batch_size: int = 8
    seq_len: int = 512
    steps: int = 100
    seed: int = 0
    log_every: int = 10
