"""xLSTM 1.3B [arXiv:2405.04517]. 48 blocks, d_model=2048, 4 heads, d_ff=0
(blocks integrate their own FF), vocab=50304, xLSTM[7:1]: superblock of
8 = 7 mLSTM + 1 sLSTM. Pure recurrent state -> long_500k runs with O(1)
per-token memory."""
from repro.configs.base import BlockSpec, ModelConfig, SSMConfig
from repro.configs.catalog import reduce_for_smoke

_PATTERN = tuple(
    BlockSpec(mixer="slstm" if i == 3 else "mlstm", mlp="none") for i in range(8)
)

CONFIG = ModelConfig(
    name="xlstm_1_3b",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=48,
    d_model=2048,
    d_ff=0,
    vocab_size=50304,
    max_seq_len=524288,
    ssm=SSMConfig(kind="mlstm", num_heads=4, proj_factor=2.0),
    pattern=_PATTERN,
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE_CONFIG = reduce_for_smoke(
    CONFIG,
    num_layers=2,
    pattern=(BlockSpec("mlstm", "none"), BlockSpec("slstm", "none")),
)
