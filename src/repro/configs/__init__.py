from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    INPUT_SHAPES,
    InputShape,
    MoEConfig,
    ModelConfig,
    OptimizerConfig,
    PipelineConfig,
    SSMConfig,
    TrainConfig,
)
from repro.configs.catalog import ARCH_IDS, PAPER_IDS, all_configs, get_config, shapes_for

__all__ = [
    "AttentionConfig",
    "BlockSpec",
    "INPUT_SHAPES",
    "InputShape",
    "MoEConfig",
    "ModelConfig",
    "OptimizerConfig",
    "PipelineConfig",
    "SSMConfig",
    "TrainConfig",
    "ARCH_IDS",
    "PAPER_IDS",
    "all_configs",
    "get_config",
    "shapes_for",
]
