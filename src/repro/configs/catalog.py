"""Catalog of assigned architectures (public-literature pool) + paper models.

Each architecture module exports ``CONFIG`` (the exact assigned configuration,
exercised only through the AOT dry-run — never materialised on CPU) and
``SMOKE_CONFIG`` (a reduced same-family variant: <=2 superblocks, d_model<=512,
<=4 experts) that the test-suite instantiates and steps for real.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    InputShape,
    INPUT_SHAPES,
    MoEConfig,
    ModelConfig,
    SSMConfig,
)

ARCH_IDS: List[str] = [
    "llava_next_34b",
    "mixtral_8x22b",
    "stablelm_1_6b",
    "qwen3_0_6b",
    "qwen1_5_0_5b",
    "phi4_mini_3_8b",
    "jamba_v0_1_52b",
    "deepseek_v2_236b",
    "xlstm_1_3b",
    "musicgen_large",
]

PAPER_IDS = ["paper_95m", "paper_1b", "paper_3b"]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS + PAPER_IDS}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod_name = _ALIAS.get(name, name).replace("-", "_")
    variant = None
    if mod_name.endswith("_swa"):  # beyond-paper sliding-window variants
        mod_name, variant = mod_name[: -len("_swa")], "SWA_CONFIG"
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    if smoke:
        return mod.SMOKE_CONFIG
    return getattr(mod, variant) if variant else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


def shapes_for(cfg: ModelConfig) -> List[InputShape]:
    """Input shapes applicable to an architecture (long_500k policy: DESIGN §6)."""
    out = [INPUT_SHAPES["train_4k"], INPUT_SHAPES["prefill_32k"], INPUT_SHAPES["decode_32k"]]
    if cfg.supports_long_context():
        out.append(INPUT_SHAPES["long_500k"])
    return out


# ---------------------------------------------------------------------------
# Shared smoke-reduction helper
# ---------------------------------------------------------------------------


def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config to CPU-steppable size while keeping its family traits."""
    d_model = min(cfg.d_model, 256)
    att = cfg.attention
    if att.kind == "mla":
        att = AttentionConfig(
            kind="mla",
            num_heads=4,
            num_kv_heads=4,
            qk_norm=att.qk_norm,
            rope_theta=att.rope_theta,
            q_lora_rank=48 if att.q_lora_rank else 0,
            kv_lora_rank=32,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        )
    else:
        n_heads = 4
        n_kv = max(1, min(att.num_kv_heads * n_heads // max(att.num_heads, 1), n_heads))
        att = AttentionConfig(
            kind="gqa",
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=32,
            qk_norm=att.qk_norm,
            qkv_bias=att.qkv_bias,
            window=min(att.window, 64) if att.window else None,
            rope_theta=att.rope_theta,
        )
    moe = cfg.moe
    if moe is not None:
        moe = MoEConfig(
            num_experts=min(moe.num_experts, 4),
            top_k=min(moe.top_k, 2),
            num_shared=min(moe.num_shared, 1),
            d_ff_expert=64,
            aux_loss_coef=moe.aux_loss_coef,
        )
    ssm = cfg.ssm
    if ssm is not None:
        ssm = SSMConfig(
            kind=ssm.kind,
            d_state=8,
            d_conv=4,
            expand=2,
            num_heads=4,
            proj_factor=ssm.proj_factor,
        )
    # one superblock of the (possibly shortened) pattern
    pattern = cfg.pattern if len(cfg.pattern) <= 2 else cfg.pattern[:2]
    kw = dict(
        name=cfg.name + "_smoke",
        num_layers=len(pattern),
        d_model=d_model,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 2048),
        max_seq_len=256,
        attention=att,
        moe=moe,
        ssm=ssm,
        pattern=pattern,
        frontend_tokens=4 if cfg.frontend else 0,
        frontend_dim=32 if cfg.frontend else 0,
    )
    kw.update(overrides)
    return cfg.replace(**kw)
