"""MusicGen-large [arXiv:2306.05284]. 48L decoder over EnCodec tokens:
d_model=2048, 32 heads (MHA), d_ff=8192, 4 codebooks x vocab=2048 summed at
the input and predicted by 4 heads. The EnCodec conv codec is STUBBED per the
brief — inputs are token ids in the 4 codebooks. Full attention -> long_500k
skipped."""
from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig
from repro.configs.catalog import reduce_for_smoke

CONFIG = ModelConfig(
    name="musicgen_large",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=2048,
    d_ff=8192,
    vocab_size=2048,
    max_seq_len=32768,
    attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=64),
    pattern=(BlockSpec("attn", "dense"),),
    norm="layernorm",
    mlp_act="gelu",
    num_codebooks=4,
    frontend="audio",
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE_CONFIG = reduce_for_smoke(
    CONFIG, num_layers=2, pattern=(BlockSpec("attn", "dense"),) * 2, vocab_size=128
)
