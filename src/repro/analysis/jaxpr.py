"""Jaxpr auditor: reusable traversal + named structural checks.

The paper's correctness story is *structural*: per-stage delay FIFOs of exact
depth, O(stages) live activations under 1F1B, vocab-sized matmuls gated to
the last stage, fp32 state everywhere. None of these properties shows up in a
loss curve until it has silently rotted — so they are enforced here, on the
traced program itself, before any numbers run.

Layer 1 of the static-analysis subsystem (DESIGN.md §8):

* a traversal API (`iter_eqns`, `sub_jaxprs`, `n_eqns`, `max_float_bytes`,
  `float_dtypes`, `vocab_dot_counts`, `leading_dims_of`) that walks every
  equation including `scan`/`cond`/`pjit`/`while`/remat sub-jaxprs — the
  single walker the tests and the matrix runner share;
* named checks, each returning a `CheckResult`:
    - ``scan_body_constant_in_microbatches``: jaxpr size (and, for 1F1B,
      the largest live float buffer) is O(1) in the microbatch count M;
    - ``no_dot_outside_cond``: vocab-sized `dot_general`s inside scanned
      tick bodies must sit under a `lax.cond` branch (last-stage gating);
    - ``dtype_policy``: no f64 anywhere; every float intermediate drawn
      from an allowed compute set and every float *input* (params,
      optimizer state) in the declared state dtype — the hook the bf16-
      compute/f32-state ROADMAP item plugs into;
    - ``stash_bound``: no activation-shaped float buffer exceeds the
      2K-1 input-stash slots of the 1F1B schedule.

Checks never assert: they return pass/fail plus the measured evidence, so
the runner can aggregate a matrix into one JSON report and the tests can
assert on exactly one property at a time.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Check result
# ---------------------------------------------------------------------------


@dataclass
class CheckResult:
    """Outcome of one named check: verdict + the evidence it measured."""

    name: str
    passed: bool
    detail: str = ""
    data: Dict[str, Any] = field(default_factory=dict)

    def __bool__(self) -> bool:
        return self.passed

    def to_json(self) -> Dict[str, Any]:
        return asdict(self)


# ---------------------------------------------------------------------------
# Traversal
# ---------------------------------------------------------------------------


def as_jaxpr(jx: Any) -> Any:
    """Unwrap a ClosedJaxpr (or `make_jaxpr` result) to the underlying Jaxpr."""
    return jx.jaxpr if hasattr(jx, "jaxpr") else jx


def sub_jaxprs(eqn: Any) -> List[Any]:
    """Sub-jaxprs of one equation: scan/while bodies, cond branches, pjit
    and custom-vjp calls, remat — anything a params value holds, including
    tuples of branches."""
    out: List[Any] = []
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for w in items:
            if hasattr(w, "jaxpr"):
                out.append(w.jaxpr)
            elif hasattr(w, "eqns"):
                out.append(w)
    return out


def iter_eqns(jx: Any, _ctx: Tuple[str, ...] = ()) -> Iterator[Tuple[Any, Tuple[str, ...]]]:
    """Yield ``(eqn, ctx)`` for every equation, recursing into sub-jaxprs.

    ``ctx`` is the tuple of enclosing primitive names (outermost first), so
    ``"scan" in ctx`` means "inside a scanned body" and ``"cond" in ctx``
    means "under a cond branch".
    """
    jx = as_jaxpr(jx)
    for eq in jx.eqns:
        yield eq, _ctx
        inner = _ctx + (eq.primitive.name,)
        for sj in sub_jaxprs(eq):
            yield from iter_eqns(sj, inner)


def n_eqns(jx: Any) -> int:
    """Total equation count including every sub-jaxpr."""
    return sum(1 for _ in iter_eqns(jx))


def _avals_of(eqn: Any) -> Iterator[Any]:
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape"):
            yield aval


def iter_avals(jx: Any) -> Iterator[Tuple[Any, Any, Tuple[str, ...]]]:
    """Yield ``(aval, eqn, ctx)`` for every shaped aval in the program,
    plus the top-level inputs as ``(aval, None, ())``."""
    for v in list(as_jaxpr(jx).invars) + list(as_jaxpr(jx).constvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape"):
            yield aval, None, ()
    for eq, ctx in iter_eqns(jx):
        for aval in _avals_of(eq):
            yield aval, eq, ctx


def _is_float(aval: Any) -> bool:
    return jnp.issubdtype(aval.dtype, jnp.floating)


def max_float_bytes(jx: Any) -> int:
    """Largest floating-point intermediate anywhere in the program — the
    schedule's activation buffers dominate, so this is the O(M)-vs-O(K)
    live-memory story (integer token/label inputs are excluded)."""
    best = 0
    for aval, _eq, _ctx in iter_avals(jx):
        if _is_float(aval):
            best = max(best, aval.size * aval.dtype.itemsize)
    return best


def float_dtypes(jx: Any) -> Dict[str, int]:
    """Histogram of float dtype names appearing anywhere in the program."""
    out: Dict[str, int] = {}
    for aval, _eq, _ctx in iter_avals(jx):
        if _is_float(aval):
            name = jnp.dtype(aval.dtype).name
            out[name] = out.get(name, 0) + 1
    return out


def vocab_dot_counts(jx: Any, vocab: int) -> Dict[str, int]:
    """Count `dot_general`s with a vocab-sized float output inside scanned
    bodies, split by whether they sit under a `lax.cond` branch."""
    counts = {"outside_cond": 0, "inside_cond": 0}
    for eq, ctx in iter_eqns(jx):
        if "scan" not in ctx or eq.primitive.name != "dot_general":
            continue
        hit = any(
            getattr(v.aval, "shape", ()) and v.aval.shape[-1] == vocab
            and _is_float(v.aval)
            for v in eq.outvars
        )
        if hit:
            counts["inside_cond" if "cond" in ctx else "outside_cond"] += 1
    return counts


def leading_dims_of(jx: Any, trailing_shape: Sequence[int]) -> List[int]:
    """Leading dims of every float aval shaped ``(q, *trailing_shape)`` —
    the slot counts of stacked activation buffers (the 1F1B input stash)."""
    trail = tuple(trailing_shape)
    out = []
    for aval, _eq, _ctx in iter_avals(jx):
        if (
            _is_float(aval)
            and len(aval.shape) == len(trail) + 1
            and tuple(aval.shape[1:]) == trail
        ):
            out.append(int(aval.shape[0]))
    return out


# ---------------------------------------------------------------------------
# Named checks
# ---------------------------------------------------------------------------


def check_scan_body_constant_in_microbatches(
    jaxprs_by_m: Mapping[int, Any],
    expect_const_bytes: bool = True,
    name: str = "scan_body_constant_in_microbatches",
) -> CheckResult:
    """The traced program must be O(1) in the microbatch count M.

    Both schedules scan their tick body, so the equation count must be
    identical across M. ``expect_const_bytes=True`` (1F1B) additionally pins
    the largest live float buffer — the O(K) stash property;
    ``expect_const_bytes=False`` (fill-drain) instead demands the buffer
    *grows* with M, which proves the measurement sees schedule memory and is
    not vacuously constant.
    """
    ms = sorted(jaxprs_by_m)
    if len(ms) < 2:
        return CheckResult(name, False, f"need >= 2 microbatch counts, got {ms}")
    eqns = {m: n_eqns(jaxprs_by_m[m]) for m in ms}
    fbytes = {m: max_float_bytes(jaxprs_by_m[m]) for m in ms}
    ok_eqns = len(set(eqns.values())) == 1
    if expect_const_bytes:
        ok_bytes = len(set(fbytes.values())) == 1
        want = "constant"
    else:
        ok_bytes = all(fbytes[a] < fbytes[b] for a, b in zip(ms, ms[1:]))
        want = "growing"
    detail = "" if (ok_eqns and ok_bytes) else (
        f"eqns {eqns} must be constant in M; max float bytes {fbytes} "
        f"must be {want} in M"
    )
    return CheckResult(
        name, ok_eqns and ok_bytes, detail,
        {"eqns": eqns, "max_float_bytes": fbytes},
    )


def check_no_dot_outside_cond(
    jx: Any,
    vocab: int,
    require_gated: bool = True,
    name: str = "no_dot_outside_cond",
) -> CheckResult:
    """No vocab-sized matmul in a scanned tick body outside a `lax.cond`.

    The O(vocab) LM-head matmul (and its vjp) must only run on the last
    stage's branch; an ungated one makes every stage pay for the head every
    tick. ``require_gated=True`` (1F1B) also demands the gated dot exists —
    a check that finds zero dots anywhere is measuring the wrong program.
    """
    counts = vocab_dot_counts(jx, vocab)
    ok = counts["outside_cond"] == 0
    if require_gated:
        ok = ok and counts["inside_cond"] >= 1
    detail = "" if ok else (
        f"vocab({vocab})-sized dot_generals in scanned bodies: {counts}; "
        "expected 0 outside lax.cond"
        + (" and >= 1 inside" if require_gated else "")
    )
    return CheckResult(name, ok, detail, dict(counts))


@dataclass(frozen=True)
class DtypePolicy:
    """Float-dtype discipline for one traced program.

    ``allowed_float`` bounds every float intermediate, ``forbidden`` is
    rejected anywhere (f64 creeps in through numpy scalars and x64 mode),
    and ``state_dtype`` — when set — pins the dtype of every float *input*
    of the program: parameters and optimizer state. The repo today is
    fp32-everywhere (`F32_POLICY`); `BF16_COMPUTE_POLICY` is the planned
    mixed-precision regime where intermediates may be bf16 but master
    params/moments stay fp32.
    """

    allowed_float: Tuple[str, ...] = ("float32",)
    forbidden: Tuple[str, ...] = ("float64",)  # lint: allow-float64
    state_dtype: Optional[str] = "float32"
    # dtypes that MUST appear among the intermediates — makes a permissive
    # policy non-vacuous: a "bf16 compute" program with no bf16 anywhere is
    # an f32 program wearing the wrong flag
    require_present: Tuple[str, ...] = ()


F32_POLICY = DtypePolicy()
BF16_COMPUTE_POLICY = DtypePolicy(
    allowed_float=("float32", "bfloat16"), state_dtype="float32",
    require_present=("bfloat16",),
)


def check_dtype_policy(
    jx: Any,
    policy: DtypePolicy = F32_POLICY,
    name: str = "dtype_policy",
) -> CheckResult:
    """Every float aval satisfies the policy (see `DtypePolicy`)."""
    bad: List[str] = []
    seen = float_dtypes(jx)
    for dt in seen:
        if dt in policy.forbidden:
            bad.append(f"forbidden dtype {dt} appears {seen[dt]}x")
        elif dt not in policy.allowed_float:
            bad.append(f"dtype {dt} not in allowed set {policy.allowed_float}")
    for dt in policy.require_present:
        if dt not in seen:
            bad.append(
                f"required dtype {dt} appears nowhere (policy is vacuous)"
            )
    if policy.state_dtype is not None:
        jxp = as_jaxpr(jx)
        for i, v in enumerate(list(jxp.invars) + list(jxp.constvars)):
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            if _is_float(aval) and jnp.dtype(aval.dtype).name != policy.state_dtype:
                bad.append(
                    f"input #{i} has state dtype {jnp.dtype(aval.dtype).name}, "
                    f"policy requires {policy.state_dtype}"
                )
    return CheckResult(name, not bad, "; ".join(bad), {"float_dtypes": seen})


def check_pallas_in_scan(
    jx: Any,
    min_calls: int = 3,
    name: str = "kernel_in_scan",
) -> CheckResult:
    """`pallas_call`s must run inside the scanned tick body.

    Under ``use_kernels`` the stage apply is the fused flash attention —
    one forward kernel plus the two custom-vjp backward kernels (dQ and
    dK/dV), all of which must appear *inside* a `lax.scan` body: a kernel
    hoisted out of the scan means the schedule stopped calling it per tick
    (e.g. the custom_vjp got inlined away by a rewrite). ``min_calls``
    defaults to the fwd + 2 bwd kernels of one attention site.
    """
    in_scan = 0
    outside = 0
    for eq, ctx in iter_eqns(jx):
        if eq.primitive.name != "pallas_call":
            continue
        if "scan" in ctx:
            in_scan += 1
        else:
            outside += 1
    ok = in_scan >= min_calls
    detail = "" if ok else (
        f"{in_scan} pallas_call(s) inside scan bodies (need >= {min_calls}); "
        f"{outside} outside"
    )
    return CheckResult(
        name, ok, detail, {"in_scan": in_scan, "outside_scan": outside}
    )


def check_stash_bound(
    jx: Any,
    num_stages: int,
    activation_shape: Sequence[int],
    name: str = "stash_bound",
) -> CheckResult:
    """The 1F1B input stash never exceeds its 2K-1 slots.

    Stage k re-reads its forward input 2(K-1-k) ticks later, so 2K-1 slots
    are necessary and sufficient; a wider stash silently reintroduces the
    O(M) memory the schedule exists to avoid. Every float buffer stacked
    over the activation shape ``(mb, S, d)`` must have <= 2K-1 slots, and
    the stash itself (exactly 2K-1) must be present — a trace with no
    stacked activation buffer at all is measuring the wrong program.
    """
    bound = 2 * num_stages - 1
    dims = leading_dims_of(jx, activation_shape)
    over = [d for d in dims if d > bound]
    ok = not over and bound in dims
    detail = "" if ok else (
        f"activation{tuple(activation_shape)} buffers with slot counts {dims}; "
        f"bound 2K-1 = {bound}"
        + ("" if bound in dims else " (expected stash not found)")
    )
    return CheckResult(
        name, ok, detail, {"slot_counts": dims, "bound": bound}
    )
