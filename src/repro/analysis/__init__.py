"""Static-analysis subsystem: jaxpr, HLO, and AST invariant checks.

Three layers (DESIGN.md §8), all pure inspection — nothing here executes a
training step:

* `repro.analysis.jaxpr` — traversal API + named checks on traced programs
  (O(1)-in-M scan bodies, cond-gated vocab matmuls, dtype policy, the
  2K-1 stash bound);
* `repro.analysis.hlo` — the collective parser (shared with
  `launch/roofline.py`) + replica-group checks against `Topology`;
* `repro.analysis.lint` — AST rules over ``src/repro`` source.

`repro.analysis.runner` drives all of it over the engine matrix:
``python -m repro.analysis --matrix smoke``.
"""
from repro.analysis.jaxpr import (
    BF16_COMPUTE_POLICY,
    CheckResult,
    DtypePolicy,
    F32_POLICY,
    as_jaxpr,
    check_dtype_policy,
    check_no_dot_outside_cond,
    check_pallas_in_scan,
    check_scan_body_constant_in_microbatches,
    check_stash_bound,
    float_dtypes,
    iter_avals,
    iter_eqns,
    leading_dims_of,
    max_float_bytes,
    n_eqns,
    sub_jaxprs,
    vocab_dot_counts,
)
from repro.analysis.hlo import (
    COLLECTIVE_OPS,
    CollectiveInstr,
    CollectiveStats,
    check_async_step_reduction,
    check_collective_axes,
    check_data_reduction,
    collective_stats,
    declared_groupings,
    parse_collectives,
)
from repro.analysis.lint import (
    LintFinding,
    check_repo_lint,
    lint_file,
    lint_source,
    lint_tree,
)

__all__ = [
    "BF16_COMPUTE_POLICY",
    "CheckResult",
    "DtypePolicy",
    "F32_POLICY",
    "as_jaxpr",
    "check_dtype_policy",
    "check_no_dot_outside_cond",
    "check_pallas_in_scan",
    "check_scan_body_constant_in_microbatches",
    "check_stash_bound",
    "float_dtypes",
    "iter_avals",
    "iter_eqns",
    "leading_dims_of",
    "max_float_bytes",
    "n_eqns",
    "sub_jaxprs",
    "vocab_dot_counts",
    "COLLECTIVE_OPS",
    "CollectiveInstr",
    "CollectiveStats",
    "check_async_step_reduction",
    "check_collective_axes",
    "check_data_reduction",
    "collective_stats",
    "declared_groupings",
    "parse_collectives",
    "LintFinding",
    "check_repo_lint",
    "lint_file",
    "lint_source",
    "lint_tree",
]
