"""HLO collective auditor: parse optimized modules, verify collective axes.

Layer 2 of the static-analysis subsystem (DESIGN.md §8). The instruction
scanner here is THE collective parser — `launch/roofline.py` re-exports it
for its bandwidth accounting, and the checks below reuse the same parse to
enforce *which* collective runs over *which* mesh axis:

* every `all-reduce` / `all-gather` / `reduce-scatter` / `all-to-all` must
  run over replica groups that exactly match one axis subset declared by
  `Topology` (`Topology.replica_groups`) — a group that mixes device
  coordinates diagonally is a mis-sharded reduction no loss curve will
  reliably surface;
* every `collective-permute` must move along the stage axis only (the
  pipeline's fwd/bwd neighbour shifts) — pairs crossing the data or pod
  axis mean activations are leaking between replicas;
* the combined data-axes gradient all-reduce — spanning ``("pod", "data")``
  on multi-pod shapes — must be present iff the topology has more than one
  data shard (pod+data pmean present iff pods > 1 in the data=1 matrix).

Replica groups are parsed in both textual forms XLA emits: the explicit
``replica_groups={{0,1},{2,3}}`` and the iota form
``replica_groups=[2,2]<=[4]`` / ``[G,S]<=[d0,..]T(p0,..)``. Group members
are flattened positions in the mesh's device assignment (row-major over the
(pod, stage, data) shape), which is exactly what `Topology.replica_groups`
returns.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.analysis.jaxpr import CheckResult

# ---------------------------------------------------------------------------
# Instruction scanner (shared with launch/roofline.py)
# ---------------------------------------------------------------------------

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  f32[128,1024]{1,0}   or  bf16[2,8]   or tuple elements
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# one HLO instruction: "%name = <output type(s)> <op>(...)" — each collective
# is billed by its OUTPUT type(s), which works uniformly for single and
# tuple-combined collectives (optimized HLO prints operands as bare
# instruction references without types). For all-reduce / all-to-all /
# collective-permute output size == operand size; for all-gather it is the
# gathered (larger) size and for reduce-scatter the scattered (smaller) one —
# both are natural per-device traffic proxies.
INSTR_RE = re.compile(r"=\s*(\([^)]*\)|\S+)\s+([\w-]+?)(-start|-done)?\(")

_GROUPS_RE = re.compile(r"replica_groups=(\{\{.*?\}\}|\{\})")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+(?:,\d+)*)\]<=\[(\d+(?:,\d+)*)\]"
    r"(?:T\((\d+(?:,\d+)*)\))?"
)
_PAIRS_RE = re.compile(r"source_target_pairs=(\{\{.*?\}\})")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


@dataclass
class CollectiveInstr:
    """One parsed collective instruction of an optimized HLO module."""

    op: str  # base opcode, e.g. "all-reduce" (async -start folded in)
    out_bytes: int
    replica_groups: Optional[Tuple[Tuple[int, ...], ...]] = None
    source_target_pairs: Optional[Tuple[Tuple[int, int], ...]] = None
    line: str = ""


def _parse_brace_groups(text: str) -> Tuple[Tuple[int, ...], ...]:
    """``{{0,1},{2,3}}`` -> ((0, 1), (2, 3)); ``{}`` -> ()."""
    if text == "{}":
        return ()
    return tuple(
        tuple(int(x) for x in grp.replace(" ", "").split(",") if x)
        for grp in re.findall(r"\{([\d,\s]*)\}", text[1:-1])
    )


def _parse_iota_groups(
    group_dims: str, reshape_dims: str, perm: Optional[str]
) -> Tuple[Tuple[int, ...], ...]:
    """Expand the iota replica-group form to explicit groups.

    ``[G,S]<=[d0,d1,..]T(p0,p1,..)``: take ``arange(prod(d))``, reshape to
    the d-dims, transpose by the permutation (identity when absent), then
    reshape to (num_groups, group_size) row-major.
    """
    import numpy as np

    gdims = [int(x) for x in group_dims.split(",")]
    rdims = [int(x) for x in reshape_dims.split(",")]
    ids = np.arange(int(np.prod(rdims))).reshape(rdims)
    if perm:
        ids = ids.transpose([int(x) for x in perm.split(",")])
    ids = ids.reshape(-1)
    # trailing group dims are the group size; leading are the group count
    size = gdims[-1]
    return tuple(
        tuple(int(x) for x in row) for row in ids.reshape(-1, size)
    )


def parse_collectives(hlo_text: str) -> List[CollectiveInstr]:
    """Every collective instruction in (optimized) HLO text, with replica
    groups / source-target pairs decoded to flattened device positions."""
    out: List[CollectiveInstr] = []
    for line in hlo_text.splitlines():
        m = INSTR_RE.search(line)
        if not m:
            continue
        out_types, base, suffix = m.group(1), m.group(2), m.group(3)
        if base not in COLLECTIVE_OPS:
            continue
        if suffix == "-done":
            continue  # counted at -start
        nbytes = sum(
            shape_bytes(d, dims) for d, dims in SHAPE_RE.findall(out_types)
        )
        groups: Optional[Tuple[Tuple[int, ...], ...]] = None
        gm = _GROUPS_RE.search(line)
        if gm:
            groups = _parse_brace_groups(gm.group(1))
        else:
            im = _IOTA_RE.search(line)
            if im:
                groups = _parse_iota_groups(*im.groups())
        pairs: Optional[Tuple[Tuple[int, int], ...]] = None
        pm = _PAIRS_RE.search(line)
        if pm:
            pairs = tuple(
                (int(a), int(b))
                for a, b in re.findall(r"\{(\d+),\s*(\d+)\}", pm.group(1))
            )
        out.append(
            CollectiveInstr(
                op=base, out_bytes=nbytes, replica_groups=groups,
                source_target_pairs=pairs, line=line.strip(),
            )
        )
    return out


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output-type bytes of every collective op in (optimized) HLO text."""
    stats = CollectiveStats()
    for ins in parse_collectives(hlo_text):
        stats.bytes_by_op[ins.op] = stats.bytes_by_op.get(ins.op, 0) + ins.out_bytes
        stats.count_by_op[ins.op] = stats.count_by_op.get(ins.op, 0) + 1
    return stats


# ---------------------------------------------------------------------------
# Topology-declared groupings
# ---------------------------------------------------------------------------


Grouping = FrozenSet[FrozenSet[int]]


def _normalize(groups: Sequence[Sequence[int]]) -> Grouping:
    return frozenset(frozenset(g) for g in groups)


def declared_groupings(topology: Any) -> Dict[Tuple[str, ...], Grouping]:
    """Every replica grouping the topology declares: one per non-empty
    subset of mesh axes (a reduction over that subset partitions devices by
    their coordinates on the remaining axes)."""
    import itertools

    names = topology.axis_names
    out: Dict[Tuple[str, ...], Grouping] = {}
    for r in range(1, len(names) + 1):
        for subset in itertools.combinations(names, r):
            out[subset] = _normalize(topology.replica_groups(subset))
    return out


def _device_coords(topology: Any) -> Dict[int, Tuple[int, ...]]:
    """Flattened device-assignment position -> (pod, stage, data) coords."""
    import numpy as np

    shape = topology.shape
    return {
        i: tuple(int(c) for c in coords)
        for i, coords in enumerate(np.ndindex(*shape))
    }


def _instr_grouping(ins: CollectiveInstr, topology: Any) -> Optional[Grouping]:
    if ins.replica_groups is None:
        return None
    if ins.replica_groups == ():  # replica_groups={} => all devices together
        return _normalize([list(range(topology.num_devices))])
    return _normalize(ins.replica_groups)


def check_collective_axes(
    instrs: Sequence[CollectiveInstr],
    topology: Any,
    name: str = "collective_axes",
) -> CheckResult:
    """Every collective runs over a Topology-declared axis grouping.

    Reductions/gathers must match the grouping of exactly one declared axis
    subset; permutes must move along the stage axis only. Singleton-group
    collectives (degenerate axes) are accepted — XLA usually deletes them.
    """
    groupings = declared_groupings(topology)
    coords = _device_coords(topology)
    stage_dim = topology.axis_names.index("stage")
    bad: List[str] = []
    matched: Dict[str, List[str]] = {}
    for ins in instrs:
        if ins.op == "collective-permute":
            for s, t in ins.source_target_pairs or ():
                cs, ct = coords.get(s), coords.get(t)
                if cs is None or ct is None:
                    bad.append(f"permute pair ({s},{t}) outside device grid")
                    continue
                moved = [i for i in range(len(cs)) if cs[i] != ct[i]]
                if moved != [stage_dim]:
                    bad.append(
                        f"permute pair ({s},{t}) moves along dims {moved}, "
                        f"expected stage (dim {stage_dim}) only: {ins.line[:120]}"
                    )
            matched.setdefault(ins.op, []).append("stage-neighbour")
            continue
        grouping = _instr_grouping(ins, topology)
        if grouping is None:
            continue  # no group annotation (single-device module)
        if all(len(g) == 1 for g in grouping):
            matched.setdefault(ins.op, []).append("singleton")
            continue
        hits = [axes for axes, g in groupings.items() if g == grouping]
        if not hits:
            bad.append(
                f"{ins.op} over undeclared replica groups "
                f"{sorted(tuple(sorted(g)) for g in grouping)}: {ins.line[:120]}"
            )
        else:
            matched.setdefault(ins.op, []).append("+".join(hits[0]))
    return CheckResult(
        name, not bad, "; ".join(bad[:4]),
        {"matched": matched, "violations": len(bad)},
    )


# ---------------------------------------------------------------------------
# Buffer donation (input->output aliasing)
# ---------------------------------------------------------------------------

# entry-module header entries: `{out_idx}: (param_idx, {}, may-alias)` —
# jax flattens every donated argument to a scalar-indexed parameter, so the
# param-side sub-index is always `{}`; `must-alias` appears when XLA pins
# the alias rather than merely permitting it
ALIAS_ENTRY_RE = re.compile(
    r"\{(\d+)(?:,\s*\d+)*\}:\s*\((\d+),\s*\{\},\s*(?:may|must)-alias\)"
)


def parse_input_output_aliases(hlo_text: str) -> Dict[int, int]:
    """``param index -> output index`` from the compiled module's
    ``input_output_alias`` header (empty when nothing is donated)."""
    for line in hlo_text.splitlines():
        if "input_output_alias={" not in line:
            continue
        return {
            int(param): int(out)
            for out, param in ALIAS_ENTRY_RE.findall(line)
        }
    return {}


def check_donation(
    hlo_text: str,
    expected_params: Sequence[int],
    queue_params: Sequence[int] = (),
    name: str = "donation",
) -> CheckResult:
    """Every expected donated parameter is aliased to an output in the
    compiled HLO — donation can never silently regress to copying.

    ``expected_params`` are the flattened indices of the donated jit args
    minus the delay-FIFO queue leaves (``queue_params``): XLA legitimately
    declines to alias the rolled queues (jax lowers them as
    ``jax.buffer_donor``), so they are reported but not required.
    """
    aliased = set(parse_input_output_aliases(hlo_text))
    missing = [i for i in expected_params if i not in aliased]
    detail = "" if not missing else (
        f"{len(missing)}/{len(expected_params)} donated parameters not "
        f"aliased in the compiled HLO (first: {missing[:8]}); "
        f"was the step jitted without donate_argnums?"
    )
    return CheckResult(
        name, not missing, detail,
        {
            "expected": len(list(expected_params)),
            "aliased": len(aliased),
            "missing": missing[:32],
            "queue_leaves": len(list(queue_params)),
            "queue_aliased": sum(1 for i in queue_params if i in aliased),
        },
    )


def _data_all_reduce_count(
    instrs: Sequence[CollectiveInstr], topology: Any
) -> int:
    """Number of all-reduces grouped exactly over the topology's data axes
    (only groupings that actually communicate — singleton groups on a
    1-data-shard topology don't count)."""
    want = _normalize(topology.replica_groups(topology.data_axes))
    if not any(len(g) > 1 for g in want):
        return 0
    return sum(
        1 for ins in instrs
        if ins.op == "all-reduce" and _instr_grouping(ins, topology) == want
    )


def check_data_reduction(
    instrs: Sequence[CollectiveInstr],
    topology: Any,
    name: str = "data_reduction",
    deferred: bool = False,
) -> CheckResult:
    """The combined data-axes gradient all-reduce is present iff the
    topology splits data: over ``("pod", "data")`` on multi-pod shapes —
    the pod+data pmean exists exactly when pods > 1 (or data > 1).

    ``deferred=True`` audits an ASYNC-data step program, where the
    reduction has been moved off the critical path into a separate reduce
    program: the in-step data all-reduce must then be ABSENT no matter how
    many data shards the topology has (pair with
    `check_async_step_reduction` to prove the reduce program still carries
    it).

    Only collectives that actually communicate count: on a 1-data-shard
    topology the data grouping is all singletons and XLA may legitimately
    leave the degenerate pmean in place (or delete it)."""
    present = _data_all_reduce_count(instrs, topology) > 0
    need = (not deferred) and topology.data_shards > 1
    ok = present == need
    detail = "" if ok else (
        f"all-reduce over data axes {topology.data_axes} "
        f"{'missing' if need else 'present'} on topology "
        f"{topology.describe()} with {topology.data_shards} data shard(s)"
        + (" (deferred/async data mode)" if deferred else "")
    )
    return CheckResult(
        name, ok, detail,
        {"present": present, "required": need, "deferred": deferred,
         "data_axes": list(topology.data_axes)},
    )


def check_async_step_reduction(
    step_instrs: Sequence[CollectiveInstr],
    reduce_instrs: Sequence[CollectiveInstr],
    topology: Any,
    name: str = "async_data_reduction",
) -> CheckResult:
    """Async data mode invariant, checked over the step/reduce program PAIR:
    the train-step HLO contains NO all-reduce grouped over the data axes
    (the critical path is communication-free along data), and the deferred
    reduce program contains AT LEAST ONE (the reduction was moved, not
    lost). On a 1-data-shard topology only the absence half applies."""
    in_step = _data_all_reduce_count(step_instrs, topology)
    in_reduce = _data_all_reduce_count(reduce_instrs, topology)
    need_reduce = topology.data_shards > 1
    bad: List[str] = []
    if in_step:
        bad.append(
            f"{in_step} data-axes all-reduce(s) on the async step critical "
            f"path (axes {topology.data_axes})"
        )
    if need_reduce and not in_reduce:
        bad.append(
            f"deferred reduce program has no all-reduce over data axes "
            f"{topology.data_axes} — the gradient reduction was lost, not "
            f"deferred"
        )
    return CheckResult(
        name, not bad, "; ".join(bad),
        {"in_step": in_step, "in_reduce": in_reduce,
         "required_in_reduce": need_reduce,
         "data_axes": list(topology.data_axes)},
    )
