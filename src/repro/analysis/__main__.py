"""``python -m repro.analysis``: run the static invariant matrix.

Forces enough host devices for the largest topology in the matrix (the
2-pod cell needs pods*stages*data) BEFORE jax initializes a backend, same
discipline as the dry-run entry points.
"""
import os
import sys

from repro.analysis.runner import required_devices
from repro.launch.devices import ensure_host_devices

ensure_host_devices(required_devices())
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.analysis.runner import main  # noqa: E402

sys.exit(main())
