"""``python -m repro.analysis``: run the static invariant matrix.

Forces enough host devices for the largest topology in the matrix (the
2-pod cell needs pods*stages*data) BEFORE jax initializes a backend, same
discipline as the dry-run entry points.
"""
import os
import sys

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    from repro.analysis.runner import required_devices

    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={required_devices()}"
    ).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.analysis.runner import main  # noqa: E402

sys.exit(main())
