"""AST lint: repo-wide source rules the jaxpr/HLO auditors cannot see.

Layer 3 of the static-analysis subsystem (DESIGN.md §8). Three rules over
every Python file in ``src/repro``:

* ``no_float64_literals`` — no ``float64`` dtype literal anywhere
  (``np.float64``, ``jnp.float64``, ``"float64"`` strings): host-side f64
  arrays either fail under jit or silently double checkpoint/bandwidth
  budgets. Waive a deliberate use with ``# lint: allow-float64`` on the
  line.
* ``no_numpy_in_scan_body`` — no ``np.`` / ``numpy.`` calls inside a
  function passed to ``lax.scan``: numpy executes at trace time, silently
  constant-folding what looks like per-tick work.
* ``no_python_if_on_traced_in_scan_body`` — no Python ``if`` whose test
  reads a scan-body parameter (the carry / per-tick operands are tracers;
  branching on them either fails to trace or freezes one branch at trace
  time). Use ``jnp.where`` / ``lax.cond``. Waive host-side config
  branching with ``# lint: allow-traced-if``.

Scan bodies are resolved statically: for every ``*.scan(body, ...)`` call
the first argument's function name is collected (unwrapping
``jax.checkpoint(body)`` / ``functools.partial(body, ...)``), and every
``def`` of that name in the module is linted — deliberately conservative,
since a helper named like a scan body is almost certainly one.
"""
from __future__ import annotations

import ast
import os
from dataclasses import asdict, dataclass
from typing import Any, Dict, Iterable, List, Optional, Set

from repro.analysis.jaxpr import CheckResult

RULE_F64 = "no_float64_literals"
RULE_SCAN_NP = "no_numpy_in_scan_body"
RULE_SCAN_IF = "no_python_if_on_traced_in_scan_body"

# spelled split so the linter does not flag its own needle
_F64 = "float" + "64"


@dataclass
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def to_json(self) -> Dict[str, Any]:
        return asdict(self)


def _waived(src_lines: List[str], lineno: int, tag: str) -> bool:
    if 1 <= lineno <= len(src_lines):
        return f"lint: allow-{tag}" in src_lines[lineno - 1]
    return False


def _first_name(node: ast.AST) -> Optional[str]:
    """Function name referenced by a scan-body argument, unwrapping
    ``jax.checkpoint(body)`` / ``partial(body, ...)`` style wrappers."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        for arg in node.args:
            name = _first_name(arg)
            if name:
                return name
    return None


def _scan_body_names(tree: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr == "scan" and node.args:
            name = _first_name(node.args[0])
            if name:
                names.add(name)
    return names


def _numpy_root(node: ast.AST) -> bool:
    while isinstance(node, ast.Attribute):
        node = node.value
    return isinstance(node, ast.Name) and node.id in ("np", "numpy")


def _lint_scan_body(
    fn: ast.FunctionDef, path: str, src_lines: List[str]
) -> List[LintFinding]:
    out: List[LintFinding] = []
    params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if _numpy_root(node.func):
                out.append(LintFinding(
                    path, node.lineno, RULE_SCAN_NP,
                    f"numpy call `{ast.unparse(node.func)}` inside scan body "
                    f"`{fn.name}` runs at trace time, not per tick",
                ))
        elif isinstance(node, ast.If):
            used = {
                n.id for n in ast.walk(node.test) if isinstance(n, ast.Name)
            }
            traced = sorted(used & params)
            if traced and not _waived(src_lines, node.lineno, "traced-if"):
                out.append(LintFinding(
                    path, node.lineno, RULE_SCAN_IF,
                    f"Python `if` on scan-body parameter(s) {traced} in "
                    f"`{fn.name}`: use jnp.where / lax.cond",
                ))
    return out


def lint_source(src: str, path: str = "<string>") -> List[LintFinding]:
    tree = ast.parse(src)
    src_lines = src.splitlines()
    out: List[LintFinding] = []

    # rule 1: float64 literals anywhere in the file
    for node in ast.walk(tree):
        hit = None
        if isinstance(node, ast.Attribute) and node.attr == _F64:
            hit = ast.unparse(node)
        elif isinstance(node, ast.Name) and node.id == _F64:
            hit = node.id
        elif isinstance(node, ast.Constant) and node.value == _F64:
            hit = repr(node.value)
        if hit is not None and not _waived(src_lines, node.lineno, _F64):
            out.append(LintFinding(
                path, node.lineno, RULE_F64, f"float64 literal `{hit}`"
            ))

    # rules 2+3: inside every function passed to lax.scan
    bodies = _scan_body_names(tree)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name in bodies:
            out.extend(_lint_scan_body(node, path, src_lines))
    return out


def lint_file(path: str) -> List[LintFinding]:
    with open(path, "r") as f:
        return lint_source(f.read(), path)


def repo_root() -> str:
    """src/repro — the package this file lives in."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_tree(root: Optional[str] = None) -> List[LintFinding]:
    root = root or repo_root()
    findings: List[LintFinding] = []
    for dirpath, _dirs, files in os.walk(root):
        for fname in sorted(files):
            if fname.endswith(".py"):
                findings.extend(lint_file(os.path.join(dirpath, fname)))
    return findings


def check_repo_lint(root: Optional[str] = None) -> CheckResult:
    findings = lint_tree(root)
    detail = "; ".join(
        f"{f.path}:{f.line} [{f.rule}] {f.message}" for f in findings[:6]
    )
    return CheckResult(
        "ast_lint", not findings, detail,
        {"findings": [f.to_json() for f in findings]},
    )
