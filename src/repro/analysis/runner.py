"""Matrix driver: audit the real engine over the engine matrix.

``python -m repro.analysis --matrix smoke`` traces and compiles the REAL
`SpmdEngine` step — not a mock — over the full cross-product

    {fill_drain, 1f1b} x {sync, async} x
    {adam, basis_rotation, pipedream_lr, delay_compensation, nesterov_pp} x
    {1-pod, 2-pod}

plus the asynchronous-data-axis cells — {fill_drain, 1f1b} x
{adam, nesterov_pp} x {2data, 2pod} with ``data_async=True, data_delay=1``
— where the step/reduce HLO pair must prove the cross-replica gradient
all-reduce left the step critical path without being lost
(``--data-async-only`` runs just these, the cheap CI smoke)

on tiny shapes (2 stages, 2 microbatches, forced host devices), runs every
named check from `repro.analysis.jaxpr` / `repro.analysis.hlo` against the
jaxpr and the optimized HLO, runs the repo AST lint, and emits one JSON
report. Exit status is non-zero if anything fails — the CI `analyze` step
gates on it (DESIGN.md §8).

Which checks run where:

* per cell: ``dtype_policy`` on the step jaxpr; ``no_dot_outside_cond`` and
  ``stash_bound`` per the schedule's declared invariants
  (`engine.schedules.SCHEDULE_INVARIANTS`); ``collective_axes``,
  ``data_reduction`` and ``donation`` (donated buffers input->output
  aliased) on the compiled step's optimized HLO.
* per (schedule, topology): ``scan_body_constant_in_microbatches`` on the
  schedule's grad program at two microbatch counts (the optimizer does not
  enter the grad trace, so this is hoisted out of the optimizer axis).
* once: the AST lint over ``src/repro``.
"""
from __future__ import annotations

import argparse
import itertools
import json
import sys
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.jaxpr import (
    BF16_COMPUTE_POLICY,
    CheckResult,
    F32_POLICY,
    check_dtype_policy,
    check_no_dot_outside_cond,
    check_pallas_in_scan,
    check_scan_body_constant_in_microbatches,
    check_stash_bound,
)

SCHEDULES = ("fill_drain", "1f1b")
SYNC_MODES = ("sync", "async")
OPTIMIZERS = (
    "adam", "basis_rotation", "pipedream_lr", "delay_compensation",
    "nesterov_pp",
)
TOPOLOGIES = ("1pod", "2pod")
# async-data cells (deferred cross-replica reduction) need topologies with
# more than one data shard: "2data" splits the data axis proper, "2pod"
# reduces over the combined ("pod", "data") axes
DATA_ASYNC_TOPOLOGIES = ("2data", "2pod")
DATA_ASYNC_OPTIMIZERS = ("adam", "nesterov_pp")
_DATA_DELAY = 1
# kernel-backed / mixed-precision configurations audited on top of the base
# matrix: (precision, use_kernels) per schedule — bf16 runs must satisfy
# BF16_COMPUTE_POLICY (bf16 intermediates REQUIRED, f32 state), and every
# use_kernels run must keep its pallas_calls inside the scanned tick body
PRECISION_CELLS = (("bf16", True), ("f32", True), ("bf16", False))

# smallest shapes that keep every invariant observable: vocab distinct from
# every other dimension so vocab-sized dots are unambiguous; 2 stages so the
# delay FIFO, the cond gate, and the stash are all non-trivial
_K = 2
_M = 2
_SEQ = 8
_M_SCALING = (2, 6)  # microbatch counts for the O(1)-in-M check


def _tiny_model_cfg():
    from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig

    return ModelConfig(
        num_layers=2, d_model=16, d_ff=24, vocab_size=96, max_seq_len=32,
        attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=8),
        pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
    )


def _opt_cfg(name: str):
    from repro.configs.base import OptimizerConfig

    kw: Dict[str, Any] = dict(name=name, learning_rate=1e-3, total_steps=4,
                              schedule="constant")
    if name == "basis_rotation":
        kw.update(rotation_freq=2, stage_aware=True)
    return OptimizerConfig(**kw)


def _topology(label: str):
    from repro.launch.topology import Topology

    if label == "1pod":
        return Topology(stages=_K, data=1)
    if label == "2pod":
        return Topology(stages=_K, data=1, pods=2)
    if label == "2data":
        return Topology(stages=_K, data=2)
    raise ValueError(f"unknown topology label {label!r}")


def required_devices() -> int:
    return max(
        _topology(t).num_devices
        for t in TOPOLOGIES + DATA_ASYNC_TOPOLOGIES
    )


# ---------------------------------------------------------------------------
# Cell + grid audits
# ---------------------------------------------------------------------------


def audit_schedule_scaling(schedule: str, topo_label: str) -> CheckResult:
    """O(1)-in-M jaxpr/buffer check on the schedule's grad program."""
    import jax
    import jax.numpy as jnp

    from repro.engine.schedules import SCHEDULE_INVARIANTS, make_schedule_grad
    from repro.engine.spmd import stack_stage_params
    from repro.models import init_model

    cfg = _tiny_model_cfg()
    topo = _topology(topo_label)
    mesh = topo.make_mesh()
    shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    stacked_s, shared_s = jax.eval_shape(
        lambda p: stack_stage_params(p, cfg, _K), shapes
    )
    mb = topo.data_shards
    jaxprs = {}
    for m in _M_SCALING:
        gf = make_schedule_grad(
            cfg, mesh, _K, m, schedule=schedule,
            data_axis=topo.schedule_data_axis,
        )
        tok = jax.ShapeDtypeStruct((m, mb, _SEQ), jnp.int32)
        jaxprs[m] = jax.make_jaxpr(gf)(
            stacked_s, shared_s, {"tokens": tok, "labels": tok}
        )
    return check_scan_body_constant_in_microbatches(
        jaxprs,
        expect_const_bytes=SCHEDULE_INVARIANTS[schedule]["const_float_bytes_in_M"],
    )


def audit_cell(
    schedule: str,
    sync_mode: str,
    opt_name: str,
    topo_label: str,
    compile_hlo: bool = True,
) -> List[CheckResult]:
    """All per-cell checks against the real SpmdEngine step."""
    from repro.analysis.hlo import (
        check_collective_axes,
        check_data_reduction,
        check_donation,
        parse_collectives,
    )
    from repro.engine.schedules import SCHEDULE_INVARIANTS
    from repro.engine.spmd import SpmdEngine

    cfg = _tiny_model_cfg()
    topo = _topology(topo_label)
    inv = SCHEDULE_INVARIANTS[schedule]  # KeyError = undeclared schedule
    engine = SpmdEngine(
        cfg, _opt_cfg(opt_name), num_stages=_K, num_microbatches=_M,
        async_grads=(sync_mode == "async"), schedule=schedule, topology=topo,
        # donate=True explicitly (not "auto"): the donation-aliasing check
        # below must audit the donated compile on every host, including CPU
        # where "auto" resolves to off for step-time reasons
        donate=True,
    )
    jx = engine.step_jaxpr(seq_len=_SEQ)
    results = [check_dtype_policy(jx, F32_POLICY)]
    results.append(
        check_no_dot_outside_cond(
            jx, cfg.vocab_size, require_gated=inv["vocab_dot_gated"]
        )
    )
    if inv["stash_bound"]:
        # inside shard_map the global microbatch (data_shards rows) is split
        # over the data axes, so the per-device stash holds 1-row activations
        results.append(
            check_stash_bound(jx, _K, (1, _SEQ, cfg.d_model))
        )
    if compile_hlo:
        hlo = engine.compiled_step(seq_len=_SEQ).as_text()
        instrs = parse_collectives(hlo)
        results.append(check_collective_axes(instrs, topo))
        results.append(check_data_reduction(instrs, topo))
        # donated step: every (stacked, shared, opt_state) leaf except the
        # delay-FIFO queues must be input->output aliased in the compiled
        # module — a lost donate_argnums can never silently regress
        expected, queues = engine.donated_leaf_indices()
        results.append(check_donation(hlo, expected, queues))
    return results


def audit_data_async_cell(
    schedule: str,
    opt_name: str,
    topo_label: str,
    data_delay: int = _DATA_DELAY,
    compile_hlo: bool = True,
) -> List[CheckResult]:
    """Audit one asynchronous-data-axis cell (deferred reduction, D > 0).

    The step and reduce programs are audited as a PAIR: the step's HLO must
    carry NO all-reduce grouped over the data axes (``data_reduction`` with
    ``deferred=True``), and ``async_data_reduction`` proves the deferred
    reduce program still contains the cross-replica gradient all-reduce —
    the reduction moved off the critical path, it did not vanish. Donation
    aliasing is re-checked because the async step signature inserts the
    ``gbar`` argument after the donated (params, opt_state) triple.
    """
    from repro.analysis.hlo import (
        check_async_step_reduction,
        check_collective_axes,
        check_data_reduction,
        check_donation,
        parse_collectives,
    )
    from repro.engine.schedules import SCHEDULE_INVARIANTS
    from repro.engine.spmd import SpmdEngine

    cfg = _tiny_model_cfg()
    topo = _topology(topo_label)
    inv = SCHEDULE_INVARIANTS[schedule]
    engine = SpmdEngine(
        cfg, _opt_cfg(opt_name), num_stages=_K, num_microbatches=_M,
        async_grads=True, schedule=schedule, topology=topo,
        data_async=True, data_delay=data_delay, donate=True,
    )
    jx = engine.step_jaxpr(seq_len=_SEQ)
    results = [check_dtype_policy(jx, F32_POLICY)]
    results.append(
        check_no_dot_outside_cond(
            jx, cfg.vocab_size, require_gated=inv["vocab_dot_gated"]
        )
    )
    if compile_hlo:
        step_hlo = engine.compiled_step(seq_len=_SEQ).as_text()
        reduce_hlo = engine.compiled_reduce(seq_len=_SEQ).as_text()
        step_instrs = parse_collectives(step_hlo)
        reduce_instrs = parse_collectives(reduce_hlo)
        results.append(check_collective_axes(step_instrs, topo))
        results.append(
            check_collective_axes(
                reduce_instrs, topo, name="collective_axes_reduce"
            )
        )
        results.append(check_data_reduction(step_instrs, topo, deferred=True))
        results.append(
            check_async_step_reduction(step_instrs, reduce_instrs, topo)
        )
        expected, queues = engine.donated_leaf_indices()
        results.append(check_donation(step_hlo, expected, queues))
    return results


def audit_precision_cell(
    schedule: str, precision: str, use_kernels: bool
) -> List[CheckResult]:
    """Dtype-policy + kernel-placement checks on a precision/kernel config.

    Jaxpr-only (no HLO compile): the collective structure is precision-
    independent and already covered by the base matrix cells. The 1F1B
    structural invariants (gated vocab head, stash bound) are re-asserted
    here because `pallas_call` inside the scanned body is exactly the kind
    of rewrite that could break them.
    """
    from repro.engine.schedules import SCHEDULE_INVARIANTS
    from repro.engine.spmd import SpmdEngine

    cfg = _tiny_model_cfg()
    inv = SCHEDULE_INVARIANTS[schedule]
    engine = SpmdEngine(
        cfg, _opt_cfg("adam"), num_stages=_K, num_microbatches=_M,
        async_grads=False, schedule=schedule, topology=_topology("1pod"),
        use_kernels=use_kernels, precision=precision,
    )
    jx = engine.step_jaxpr(seq_len=_SEQ)
    policy = BF16_COMPUTE_POLICY if precision == "bf16" else F32_POLICY
    results = [check_dtype_policy(jx, policy)]
    if use_kernels:
        # one fused forward + the two custom-vjp backward kernels per site
        results.append(check_pallas_in_scan(jx, min_calls=3))
    results.append(
        check_no_dot_outside_cond(
            jx, cfg.vocab_size, require_gated=inv["vocab_dot_gated"]
        )
    )
    if inv["stash_bound"]:
        results.append(check_stash_bound(jx, _K, (1, _SEQ, cfg.d_model)))
    return results


def run_matrix(
    matrix: str = "smoke",
    optimizers: Optional[Tuple[str, ...]] = None,
    compile_hlo: bool = True,
    verbose: bool = True,
    data_async_only: bool = False,
) -> Dict[str, Any]:
    """Run the full grid + lint; return the JSON-able report.

    ``data_async_only=True`` runs just the async-data cells + lint (the
    cheap CI smoke configuration)."""
    from repro.analysis.lint import check_repo_lint

    if matrix != "smoke":
        raise ValueError(f"unknown matrix {matrix!r} (only 'smoke' exists)")
    opts = optimizers or OPTIMIZERS

    report: Dict[str, Any] = {"matrix": matrix, "cells": [], "scaling": [],
                              "precision": [], "data_async": [],
                              "lint": None, "passed": True}

    def note(tag: str, results: List[CheckResult]):
        ok = all(r.passed for r in results)
        report["passed"] = report["passed"] and ok
        if verbose:
            states = ", ".join(
                f"{r.name}={'PASS' if r.passed else 'FAIL'}" for r in results
            )
            print(f"[{'ok' if ok else 'FAIL'}] {tag}: {states}", flush=True)
        return ok

    if data_async_only:
        for schedule, opt_name, topo_label in itertools.product(
            SCHEDULES, DATA_ASYNC_OPTIMIZERS, DATA_ASYNC_TOPOLOGIES
        ):
            results = audit_data_async_cell(
                schedule, opt_name, topo_label, compile_hlo=compile_hlo
            )
            note(f"data_async {schedule}/{opt_name}/{topo_label}", results)
            report["data_async"].append({
                "schedule": schedule, "optimizer": opt_name,
                "topology": topo_label, "data_delay": _DATA_DELAY,
                "checks": [r.to_json() for r in results],
            })
        lint = check_repo_lint()
        note("ast_lint src/repro", [lint])
        report["lint"] = lint.to_json()
        return report

    for schedule, topo_label in itertools.product(SCHEDULES, TOPOLOGIES):
        res = audit_schedule_scaling(schedule, topo_label)
        note(f"scaling {schedule}/{topo_label}", [res])
        report["scaling"].append(
            {"schedule": schedule, "topology": topo_label,
             "checks": [res.to_json()]}
        )

    for schedule, sync_mode, opt_name, topo_label in itertools.product(
        SCHEDULES, SYNC_MODES, opts, TOPOLOGIES
    ):
        results = audit_cell(
            schedule, sync_mode, opt_name, topo_label, compile_hlo=compile_hlo
        )
        note(f"{schedule}/{sync_mode}/{opt_name}/{topo_label}", results)
        report["cells"].append({
            "schedule": schedule, "sync": sync_mode, "optimizer": opt_name,
            "topology": topo_label,
            "checks": [r.to_json() for r in results],
        })

    for schedule, (precision, use_kernels) in itertools.product(
        SCHEDULES, PRECISION_CELLS
    ):
        results = audit_precision_cell(schedule, precision, use_kernels)
        kern = "kernels" if use_kernels else "xla"
        note(f"precision {schedule}/{precision}/{kern}", results)
        report["precision"].append({
            "schedule": schedule, "precision": precision,
            "use_kernels": use_kernels,
            "checks": [r.to_json() for r in results],
        })

    for schedule, opt_name, topo_label in itertools.product(
        SCHEDULES, DATA_ASYNC_OPTIMIZERS, DATA_ASYNC_TOPOLOGIES
    ):
        results = audit_data_async_cell(
            schedule, opt_name, topo_label, compile_hlo=compile_hlo
        )
        note(f"data_async {schedule}/{opt_name}/{topo_label}", results)
        report["data_async"].append({
            "schedule": schedule, "optimizer": opt_name,
            "topology": topo_label, "data_delay": _DATA_DELAY,
            "checks": [r.to_json() for r in results],
        })

    lint = check_repo_lint()
    note("ast_lint src/repro", [lint])
    report["lint"] = lint.to_json()
    return report


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checker for the engine matrix",
    )
    p.add_argument("--matrix", default="smoke", help="grid to audit (smoke)")
    p.add_argument("--out", default=None, help="write the JSON report here")
    p.add_argument(
        "--optimizers", default=None,
        help="comma-separated subset of the optimizer axis (default: all)",
    )
    p.add_argument(
        "--no-hlo", action="store_true",
        help="skip the compile + collective checks (jaxpr/lint only, faster)",
    )
    p.add_argument(
        "--lint-only", action="store_true",
        help="run only the AST lint over src/repro",
    )
    p.add_argument(
        "--data-async-only", action="store_true",
        help="run only the async-data cells + lint (CI smoke)",
    )
    args = p.parse_args(argv)

    if args.lint_only:
        from repro.analysis.lint import check_repo_lint

        lint = check_repo_lint()
        report = {"matrix": None, "cells": [], "scaling": [],
                  "lint": lint.to_json(), "passed": lint.passed}
        print(f"ast_lint: {'PASS' if lint.passed else 'FAIL'} {lint.detail}")
    else:
        opts = tuple(args.optimizers.split(",")) if args.optimizers else None
        report = run_matrix(
            args.matrix, optimizers=opts, compile_hlo=not args.no_hlo,
            data_async_only=args.data_async_only,
        )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"report -> {args.out}")
    n_checks = sum(len(c["checks"]) for c in report["cells"]) + \
        sum(len(s["checks"]) for s in report["scaling"]) + \
        sum(len(p["checks"]) for p in report.get("precision", [])) + \
        sum(len(d["checks"]) for d in report.get("data_async", [])) + 1
    print(f"analysis {'PASSED' if report['passed'] else 'FAILED'} "
          f"({n_checks} check runs)")
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    sys.exit(main())
