"""Scion (Pethick et al., 2025): stochastic conditional gradient / LMO-based
optimizer with norm-constrained updates — the last preconditioned baseline in
the paper's Table 3.

Unconstrained variant: the update is the linear minimisation oracle of the
momentum over a layer-appropriate norm ball:
  * hidden matrices — spectral-norm ball: orthogonalised momentum (Newton-
    Schulz) scaled by sqrt(d_out / d_in);
  * embeddings / LM head / vectors — l1->linf ball: sign(momentum).
Like Muon it does NOT align with the Hessian eigenbasis, so the paper finds
it less delay-robust than basis rotation / SOAP (Table 3: 2.10x vs 1.27x).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layout import build_layout
from repro.optim.base import Optimizer, Schedule
from repro.optim.muon import newton_schulz_orthogonalize


def scion(
    schedule: Schedule,
    momentum: float = 0.9,
    ns_steps: int = 5,
    min_dim: int = 8,
    sign_scale: float = 0.1,
) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step, aux=None):
        lr = schedule(step)
        layout = build_layout(params, "bilateral", min_dim)
        gflat, gdef = jax.tree_util.tree_flatten(grads)
        mflat = jax.tree_util.tree_leaves(state["m"])
        new_m, ups = [], []
        for g, m, plan in zip(gflat, mflat, layout):
            g = g.astype(jnp.float32)
            m = momentum * m + (1 - momentum) * g
            if plan.rotate:  # hidden matrix: spectral-ball LMO
                o = newton_schulz_orthogonalize(m, ns_steps)
                scale = jnp.sqrt(g.shape[-2] / max(g.shape[-1], 1) + 0.0)
                ups.append(-lr * scale * o)
            else:  # embedding / head / vector: sign LMO (l1 -> linf)
                ups.append(-lr * sign_scale * jnp.sign(m))
            new_m.append(m)
        return (
            jax.tree_util.tree_unflatten(gdef, ups),
            {"m": jax.tree_util.tree_unflatten(gdef, new_m)},
        )

    return Optimizer(init, update)
