"""Delay-aware baselines: PipeDream-LR (stage-wise learning-rate scheduling,
Yang et al. 2021) and Delay Compensation (Zheng et al. 2017, Fig. 19).

Both consume the partition's staleness metadata through `StageContext`
(`repro.core.stage_aware`): PipeDream-LR takes a pytree of per-leaf delay
values that BROADCAST over each leaf — scalar ints for leaves owned by one
stage (the sim layout), ``(K, 1, ..., 1)`` per-stage arrays over the leading
stage axis for the SPMD stage-stacked layout (`StageContext.delay_scales`) —
so one stacked ``(K, per, m, n)`` leaf gets a different LR discount per
stage slice. Delay Compensation reads the stale weight snapshot the delay
FIFO queues per stage (``aux={"stale_params": ...}``); under the stacked
layout that snapshot is already the per-stage diagonal read, so the same
elementwise formula applies per stage slice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adam import adam
from repro.optim.base import Optimizer, Schedule


def pipedream_lr(
    schedule: Schedule,
    delays,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    power: float = 0.5,
) -> Optimizer:
    """Adam with per-stage LR discount lr_k = lr / (1 + tau_k)^power.

    ``delays``: pytree matching params whose leaves broadcast against the
    corresponding parameter leaf (ints, or per-stage arrays shaped
    ``(K, 1, ..., 1)`` for stage-stacked leaves).
    """
    inner = adam(schedule, beta1, beta2, eps)
    scales = jax.tree.map(
        lambda t: (1.0 + jnp.asarray(t, jnp.float32)) ** (-power), delays
    )

    def update(grads, state, params, step, aux=None):
        updates, state = inner.update(grads, state, params, step)
        updates = jax.tree.map(lambda u, s: u * s, updates, scales)
        return updates, state

    return Optimizer(inner.init, update)


def delay_compensation(
    schedule: Schedule,
    lam: float = 0.1,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    """First-order Taylor compensation of stale gradients:

        g_hat = g + lam * g * g * (w_t - w_{t-tau})

    The diagonal empirical Fisher g*g approximates the Hessian. Requires the
    stale weight snapshot via ``aux={"stale_params": ...}`` (provided by the
    delay-FIFO wrapper when ``store_params=True``).
    """
    inner = adam(schedule, beta1, beta2, eps)

    def update(grads, state, params, step, aux=None):
        if aux is not None and "stale_params" in aux:
            grads = jax.tree.map(
                lambda g, p, ps: g
                + lam * g * g * (p.astype(jnp.float32) - ps.astype(jnp.float32)),
                grads,
                params,
                aux["stale_params"],
            )
        return inner.update(grads, state, params, step)

    return Optimizer(inner.init, update)
