"""Delay-aware baselines: PipeDream-LR (stage-wise learning-rate scheduling,
Yang et al. 2021), Delay Compensation (Zheng et al. 2017, Fig. 19), and the
Nesterov async-PP optimizer (Ajanthan et al. 2025, arXiv:2505.01099).

All consume the partition's staleness metadata through `StageContext`
(`repro.core.stage_aware`): PipeDream-LR takes a pytree of per-leaf delay
values that BROADCAST over each leaf — scalar ints for leaves owned by one
stage (the sim layout), ``(K, 1, ..., 1)`` per-stage arrays over the leading
stage axis for the SPMD stage-stacked layout (`StageContext.delay_scales`) —
so one stacked ``(K, per, m, n)`` leaf gets a different LR discount per
stage slice. Delay Compensation reads the stale weight snapshot the delay
FIFO queues per stage (``aux={"stale_params": ...}``); under the stacked
layout that snapshot is already the per-stage diagonal read, so the same
elementwise formula applies per stage slice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adam import adam
from repro.optim.base import Optimizer, Schedule


def pipedream_lr(
    schedule: Schedule,
    delays,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    power: float = 0.5,
) -> Optimizer:
    """Adam with per-stage LR discount lr_k = lr / (1 + tau_k)^power.

    ``delays``: pytree matching params whose leaves broadcast against the
    corresponding parameter leaf (ints, or per-stage arrays shaped
    ``(K, 1, ..., 1)`` for stage-stacked leaves).
    """
    inner = adam(schedule, beta1, beta2, eps)
    scales = jax.tree.map(
        lambda t: (1.0 + jnp.asarray(t, jnp.float32)) ** (-power), delays
    )

    def update(grads, state, params, step, aux=None):
        updates, state = inner.update(grads, state, params, step)
        updates = jax.tree.map(lambda u, s: u * s, updates, scales)
        return updates, state

    return Optimizer(inner.init, update)


def nesterov_pp(
    schedule: Schedule,
    delays,
    beta1: float = 0.99,
    beta2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    """Delay-aware Nesterov look-ahead for async pipeline parallelism
    (Ajanthan et al. 2025, arXiv:2505.01099).

    Where plain Nesterov-Adam applies ONE extra momentum step to anticipate
    the next update, the async-PP variant extrapolates the momentum tau + 1
    applications ahead — one per step of gradient staleness — which in the
    EMA geometry collapses to the closed form

        n_t = beta1^(tau+1) * m_t + (1 - beta1^(tau+1)) * g_t

    (geometric decay of the momentum share with the look-ahead horizon). At
    tau = 0 this is exactly `optim.adam.nesterov_adam`; the second moment and
    bias corrections are standard Adam.

    ``delays``: pytree matching params of per-leaf TOTAL delays (pipeline +
    data), broadcastable over each leaf — `StageContext.delay_scales` output,
    so stage-stacked ``(K, per, ...)`` leaves get a different look-ahead
    horizon per stage slice.
    """

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    # beta1^(tau+1) per leaf; broadcastable like the delays themselves
    look = jax.tree.map(
        lambda t: jnp.asarray(beta1, jnp.float32)
        ** (1.0 + jnp.asarray(t, jnp.float32)),
        delays,
    )

    from repro.optim.base import bias_correction

    def update(grads, state, params, step, aux=None):
        lr = schedule(step)
        bc1, bc2 = bias_correction(beta1, step), bias_correction(beta2, step)
        m = jax.tree.map(
            lambda g, mm: beta1 * mm + (1 - beta1) * g.astype(jnp.float32),
            grads, state["m"])
        v = jax.tree.map(
            lambda g, vv: beta2 * vv + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
            grads, state["v"])
        updates = jax.tree.map(
            lambda g, mm, vv, lk: -lr
            * ((lk * mm + (1.0 - lk) * g.astype(jnp.float32)) / bc1)
            / (jnp.sqrt(vv / bc2) + eps),
            grads, m, v, look)
        return updates, {"m": m, "v": v}

    return Optimizer(init, update)


def delay_compensation(
    schedule: Schedule,
    lam: float = 0.1,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    """First-order Taylor compensation of stale gradients:

        g_hat = g + lam * g * g * (w_t - w_{t-tau})

    The diagonal empirical Fisher g*g approximates the Hessian. Requires the
    stale weight snapshot via ``aux={"stale_params": ...}`` (provided by the
    delay-FIFO wrapper when ``store_params=True``).
    """
    inner = adam(schedule, beta1, beta2, eps)

    def update(grads, state, params, step, aux=None):
        if aux is not None and "stale_params" in aux:
            grads = jax.tree.map(
                lambda g, p, ps: g
                + lam * g * g * (p.astype(jnp.float32) - ps.astype(jnp.float32)),
                grads,
                params,
                aux["stale_params"],
            )
        return inner.update(grads, state, params, step)

    return Optimizer(inner.init, update)
