"""Delay-aware baselines: PipeDream-LR (stage-wise learning-rate scheduling,
Yang et al. 2021) and Delay Compensation (Zheng et al. 2017, Fig. 19).

Both take a per-leaf delay map (pytree of ints matching params) produced by
`repro.pipeline.partition.delay_map`, mirroring how each pipeline stage knows
its own delay in a real deployment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adam import adam
from repro.optim.base import Optimizer, Schedule


def pipedream_lr(
    schedule: Schedule,
    delays,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    power: float = 0.5,
) -> Optimizer:
    """Adam with per-stage LR discount lr_k = lr / (1 + tau_k)^power."""
    inner = adam(schedule, beta1, beta2, eps)
    scales = jax.tree.map(lambda t: (1.0 + float(t)) ** (-power), delays)

    def update(grads, state, params, step, aux=None):
        updates, state = inner.update(grads, state, params, step)
        updates = jax.tree.map(lambda u, s: u * s, updates, scales)
        return updates, state

    return Optimizer(inner.init, update)


def delay_compensation(
    schedule: Schedule,
    lam: float = 0.1,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    """First-order Taylor compensation of stale gradients:

        g_hat = g + lam * g * g * (w_t - w_{t-tau})

    The diagonal empirical Fisher g*g approximates the Hessian. Requires the
    stale weight snapshot via ``aux={"stale_params": ...}`` (provided by the
    delay-FIFO wrapper when ``store_params=True``).
    """
    inner = adam(schedule, beta1, beta2, eps)

    def update(grads, state, params, step, aux=None):
        if aux is not None and "stale_params" in aux:
            grads = jax.tree.map(
                lambda g, p, ps: g
                + lam * g * g * (p.astype(jnp.float32) - ps.astype(jnp.float32)),
                grads,
                params,
                aux["stale_params"],
            )
        return inner.update(grads, state, params, step)

    return Optimizer(inner.init, update)
