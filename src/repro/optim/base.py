"""Functional optimizer interface (optax-style GradientTransformation).

An optimizer is a pair of pure functions:
  * ``init(params) -> state``
  * ``update(grads, state, params, step) -> (updates, new_state)``
where ``updates`` are *additive* deltas (``params + updates``).

All optimizers here keep their state as plain pytrees so they compose with
pjit sharding, the delay-FIFO wrapper (`repro.pipeline.delay`), and
checkpointing without special cases.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., Tuple[PyTree, PyTree]]  # (grads, state, params, step)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(lr: float, total_steps: int, warmup_frac: float = 0.012) -> Schedule:
    warmup = max(1, int(total_steps * warmup_frac))

    def schedule(step):
        step = step.astype(jnp.float32)
        warm = lr * (step + 1) / warmup
        progress = jnp.clip((step - warmup) / max(1, total_steps - warmup), 0.0, 1.0)
        cos = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup, warm, cos)

    return schedule


def make_schedule(name: str, lr: float, total_steps: int, warmup_frac: float) -> Schedule:
    if name == "cosine":
        return warmup_cosine_schedule(lr, total_steps, warmup_frac)
    return constant_schedule(lr)


def bias_correction(beta: float, step: jnp.ndarray) -> jnp.ndarray:
    return 1.0 - beta ** (step.astype(jnp.float32) + 1.0)
