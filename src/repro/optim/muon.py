"""Muon (Jordan et al., 2024): momentum + Newton-Schulz orthogonalisation of
2-D updates. Included for the paper's Table 3 comparison against
preconditioned optimizers — Muon does NOT align with the Hessian eigenbasis,
so the paper finds it less delay-robust than basis rotation / SOAP.
Non-matrix parameters fall back to Adam.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layout import build_layout
from repro.optim.base import Optimizer, Schedule, bias_correction


def newton_schulz_orthogonalize(G: jnp.ndarray, steps: int = 5) -> jnp.ndarray:
    """Approximate UV^T of the SVD of G via the Newton-Schulz iteration.

    The first five iterations use Jordan's tuned quintic coefficients, whose
    fixed behaviour is an oscillation BAND around 1 (singular values land in
    roughly [0.7, 1.2] — fast, but the residual plateaus near 0.3-0.4 and
    never contracts further). Any additional steps therefore switch to the
    classical cubic polynomial f(x) = 1.5x - 0.5x^3, which is a true
    contraction toward 1 on (0, sqrt(3)) and polishes the band to
    orthonormality quadratically. steps<=5 reproduces Muon's reference
    behaviour exactly.
    """
    a, b, c = 3.4445, -4.7750, 2.0315
    X = G.astype(jnp.float32)
    transpose = X.shape[-2] > X.shape[-1]
    if transpose:
        X = jnp.swapaxes(X, -1, -2)
    X = X / (jnp.linalg.norm(X, axis=(-2, -1), keepdims=True) + 1e-7)
    for i in range(steps):
        A = X @ jnp.swapaxes(X, -1, -2)
        if i < 5:
            B = b * A + c * (A @ A)
            X = a * X + B @ X
        else:
            X = 1.5 * X - 0.5 * (A @ X)
    if transpose:
        X = jnp.swapaxes(X, -1, -2)
    return X


def muon(
    schedule: Schedule,
    momentum: float = 0.95,
    beta2: float = 0.999,
    eps: float = 1e-8,
    ns_steps: int = 5,
    min_dim: int = 8,
) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step, aux=None):
        lr = schedule(step)
        layout = build_layout(params, "bilateral", min_dim)
        gflat, gdef = jax.tree_util.tree_flatten(grads)
        mflat = jax.tree_util.tree_leaves(state["m"])
        vflat = jax.tree_util.tree_leaves(state["v"])
        bc1, bc2 = bias_correction(momentum, step), bias_correction(beta2, step)
        new_m, new_v, ups = [], [], []
        for g, m, v, plan in zip(gflat, mflat, vflat, layout):
            g = g.astype(jnp.float32)
            m = momentum * m + (1 - momentum) * g
            if plan.rotate:  # matrix parameter: orthogonalised momentum
                o = newton_schulz_orthogonalize(m, ns_steps)
                # scale like Muon: sqrt(max(m,n)) RMS-matching factor
                scale = jnp.sqrt(jnp.maximum(g.shape[-2], g.shape[-1]) * 1.0) * 0.2
                ups.append(-lr * scale * o)
                new_v.append(v)
            else:
                v = beta2 * v + (1 - beta2) * jnp.square(g)
                ups.append(-lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
                new_v.append(v)
            new_m.append(m)
        return (
            jax.tree_util.tree_unflatten(gdef, ups),
            {"m": jax.tree_util.tree_unflatten(gdef, new_m),
             "v": jax.tree_util.tree_unflatten(gdef, new_v)},
        )

    return Optimizer(init, update)
