"""Adam / AdamW / AdaSGD / async-Nesterov baselines.

AdaSGD (Wang & Wiens 2020) applies one global adaptive scale — the paper uses
it (Fig. 3) to show that coordinate-wise adaptivity, not adaptivity per se, is
what basis misalignment breaks.

The Nesterov baseline follows Ajanthan et al. (2025): Adam with a Nesterov
look-ahead on the first moment (beta1 = 0.99 in the paper's setup), which
partially anticipates the staleness of delayed gradients.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, Schedule, bias_correction


def adam(
    schedule: Schedule,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params, step):
        lr = schedule(step)
        bc1, bc2 = bias_correction(beta1, step), bias_correction(beta2, step)
        m = jax.tree.map(
            lambda g, mm: beta1 * mm + (1 - beta1) * g.astype(jnp.float32),
            grads, state["m"])
        v = jax.tree.map(
            lambda g, vv: beta2 * vv + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
            grads, state["v"])
        updates = jax.tree.map(
            lambda mm, vv, p: -lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            - (lr * weight_decay * p.astype(jnp.float32) if weight_decay else 0.0),
            m, v, params)
        return updates, {"m": m, "v": v}

    return Optimizer(init, update)


def adasgd(
    schedule: Schedule,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    """Single global adaptive scale: v is the EMA of the mean squared grad."""

    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jnp.zeros((), jnp.float32),
        }

    def update(grads, state, params, step):
        lr = schedule(step)
        bc1, bc2 = bias_correction(beta1, step), bias_correction(beta2, step)
        n_total = sum(g.size for g in jax.tree.leaves(grads))
        sq_mean = (
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
            / n_total
        )
        v = beta2 * state["v"] + (1 - beta2) * sq_mean
        denom = jnp.sqrt(v / bc2) + eps
        m = jax.tree.map(
            lambda mm, g: beta1 * mm + (1 - beta1) * g.astype(jnp.float32),
            state["m"],
            grads,
        )
        updates = jax.tree.map(lambda mm: -lr * (mm / bc1) / denom, m)
        return updates, {"m": m, "v": v}

    return Optimizer(init, update)


def nesterov_adam(
    schedule: Schedule,
    beta1: float = 0.99,
    beta2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    """Adam with Nesterov-style look-ahead momentum (Ajanthan et al., 2025)."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr = schedule(step)
        bc1, bc2 = bias_correction(beta1, step), bias_correction(beta2, step)
        m = jax.tree.map(
            lambda g, mm: beta1 * mm + (1 - beta1) * g.astype(jnp.float32),
            grads, state["m"])
        v = jax.tree.map(
            lambda g, vv: beta2 * vv + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
            grads, state["v"])
        # Nesterov look-ahead: one extra momentum application
        updates = jax.tree.map(
            lambda g, mm, vv: -lr
            * ((beta1 * mm + (1 - beta1) * g.astype(jnp.float32)) / bc1)
            / (jnp.sqrt(vv / bc2) + eps),
            grads, m, v)
        return updates, {"m": m, "v": v}

    return Optimizer(init, update)
