from repro.optim.adam import adam, adasgd, nesterov_adam
from repro.optim.base import (
    Optimizer,
    apply_updates,
    clip_by_global_norm,
    constant_schedule,
    global_norm,
    make_schedule,
    warmup_cosine_schedule,
)
from repro.optim.delay_aware import delay_compensation, pipedream_lr

__all__ = [
    "adam",
    "adasgd",
    "nesterov_adam",
    "Optimizer",
    "apply_updates",
    "clip_by_global_norm",
    "constant_schedule",
    "global_norm",
    "make_schedule",
    "warmup_cosine_schedule",
    "delay_compensation",
    "pipedream_lr",
]
