"""Build optimizers (paper method + all baselines) from OptimizerConfig,
wiring the pipeline partition's staleness metadata — one `StageContext` per
parameter layout — into the stage-aware frequency schedule, the delay-aware
baselines, and the delay-FIFO wrapper.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.core.basis_rotation import basis_rotation_adam
from repro.core.stage_aware import StageContext
from repro.optim.adam import adam, adasgd, nesterov_adam
from repro.optim.base import Optimizer, make_schedule
from repro.optim.delay_aware import delay_compensation, nesterov_pp, pipedream_lr
from repro.pipeline.delay import delayed_optimizer
from repro.pipeline.partition import stage_context_for_tree


def build_optimizer(
    ocfg: OptimizerConfig,
    params: Any,
    model_cfg: ModelConfig,
    num_stages: int = 1,
    apply_delay: bool = True,
    use_kernels: bool = False,
    stage_context: Optional[StageContext] = None,
    data_delay: int = 0,
) -> Optimizer:
    """Compose base optimizer + (optionally) the gradient-staleness wrapper.

    ``stage_context`` carries the per-leaf delay/stage metadata; by default
    it is derived from the per-layer partition of ``params``
    (`stage_context_for_tree`). The SPMD engine passes
    `stage_context_for_stacked` so stacked ``(K, per, ...)`` leaves get
    per-stage delay arrays and refresh-period tuples instead of scalars.

    ``apply_delay=False`` builds the bare optimizer for the distributed
    runtime, where staleness is physical (pipeline schedule), not simulated.

    ``data_delay=D`` adds the uniform extra staleness of an asynchronous
    data axis: delay-aware bases see total delay tau + D (via the context),
    and the simulated FIFO (``apply_delay=True``) deepens every leaf's queue
    by D — the sim-backend model of a D-step deferred data reduction (a
    1-replica reduction is the identity, so delaying the gradient IS the
    deferred-reduction semantics).
    """
    sched = make_schedule(ocfg.schedule, ocfg.learning_rate, ocfg.total_steps, ocfg.warmup_frac)
    ctx = stage_context if stage_context is not None else stage_context_for_tree(
        params, model_cfg, num_stages, data_delay=data_delay
    )

    name = ocfg.name
    if name in ("adam", "adamw", "pipedream"):
        base = adam(sched, ocfg.beta1, ocfg.beta2, ocfg.eps, ocfg.weight_decay)
    elif name == "adasgd":
        base = adasgd(sched, ocfg.beta1, ocfg.beta2, ocfg.eps)
    elif name == "nesterov":
        base = nesterov_adam(sched, ocfg.nesterov_beta, ocfg.beta2, ocfg.eps)
    elif name == "nesterov_pp":
        # delay-aware Nesterov (Ajanthan et al. 2505.01099): per-leaf
        # look-ahead horizon = total delay (pipeline tau + data delay)
        base = nesterov_pp(
            sched, ctx.delay_scales(params), ocfg.nesterov_beta, ocfg.beta2,
            ocfg.eps,
        )
    elif name == "pipedream_lr":
        base = pipedream_lr(
            sched, ctx.delay_scales(params), ocfg.beta1, ocfg.beta2, ocfg.eps
        )
    elif name == "delay_compensation":
        base = delay_compensation(sched, ocfg.dc_lambda, ocfg.beta1, ocfg.beta2, ocfg.eps)
    elif name == "muon":
        from repro.optim.muon import muon

        base = muon(sched, beta2=ocfg.beta2, eps=ocfg.eps)
    elif name == "scion":
        from repro.optim.scion import scion

        base = scion(sched)
    elif name == "basis_rotation":
        if ocfg.stage_aware and num_stages > 1:
            freq = ctx.refresh_freqs(ocfg.rotation_freq, ocfg.stage_aware_reversed)
        else:
            freq = ocfg.rotation_freq
        base = basis_rotation_adam(
            sched,
            ocfg.beta1,
            ocfg.beta2,
            ocfg.eps,
            source=ocfg.rotation_source,
            geometry=ocfg.rotation_geometry,
            freq=freq,
            weight_decay=ocfg.weight_decay,
            use_kernels=use_kernels,
        )
    else:
        raise ValueError(f"unknown optimizer {name}")

    if apply_delay and (num_stages > 1 or data_delay > 0):
        delays = ctx.delay_specs()
        assert all(isinstance(d, int) for d in delays), (
            "the per-leaf FIFO wrapper needs scalar delays; stage-stacked "
            "layouts apply staleness via stage_delayed_optimizer instead"
        )
        # one FIFO imposes the total delay tau + D per leaf — grads AND the
        # delay-compensation param snapshots age uniformly by the data delay
        delays = [d + data_delay for d in delays]
        base = delayed_optimizer(
            base, delays, store_params=(name == "delay_compensation")
        )
    return base
