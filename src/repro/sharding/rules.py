"""Sharding rules: parameter/optimizer/activation PartitionSpecs per mesh.

Scheme (MaxText-style, DESIGN.md §4):
  * batch over ("pod", "data") — pure data parallel between pods;
  * weights tensor-parallel over "model": attention q/k/v output dim, o input
    dim, MLP hidden dim, MoE expert dim (or expert-hidden when the expert
    count doesn't divide the axis), vocab dim for embedding/head;
  * the "data" axis doubles as an FSDP axis for weights and optimizer state
    (the second matrix dim is sharded over "data" when divisible) — required
    to fit the 34B/236B configs;
  * basis-rotation state: m/v follow the parameter; U/L live on the row space
    (sharded like the rows), V/R on the column space.

Every rule degrades to None when the dimension doesn't divide the axis size,
so the same rules serve the 16x16 production mesh, the 2x16x16 multi-pod
mesh, and single-device smoke tests.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.layout import path_str

# parameter-name classification -------------------------------------------------

COL_SHARDED = (  # output dim (last) over "model"
    "w_q",
    "w_k",
    "w_v",
    "w_gate",
    "w_up",
    "q_a",
    "q_b",
    "kv_a",
    "kv_b",
    "in_proj",
    "up_proj",
    "x_proj",
    "dt_proj",
    "w_x",
    "ff_up",
    "w_i",
    "w_f",
)
ROW_SHARDED = (  # input dim (second-to-last) over "model"
    "w_o",
    "w_down",
    "out_proj",
    "down_proj",
    "ff_down",
)
EXPERT_SHARDED = ("w_gate_e", "w_up_e", "w_down_e")


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _axis(mesh_shape: Dict[str, int], name: str, dim: int) -> Optional[str]:
    return name if name in mesh_shape and _div(dim, mesh_shape[name]) else None


def param_pspec(path: str, shape: Tuple[int, ...], mesh_shape: Dict[str, int]) -> P:
    """PartitionSpec for a parameter leaf."""
    nd = len(shape)
    leaf = path.split("/")[-1]
    spec: List[Optional[Any]] = [None] * nd

    def set_last2(row_axis, col_axis):
        if nd >= 2:
            spec[-2] = row_axis
            spec[-1] = col_axis

    if "embedding" in path:
        # (V, d) or (K, V, d): vocab over model, d over data (FSDP)
        if nd >= 2:
            spec[-2] = _axis(mesh_shape, "model", shape[-2])
            spec[-1] = _axis(mesh_shape, "data", shape[-1])
    elif leaf == "lm_head":
        set_last2(_axis(mesh_shape, "data", shape[-2]), _axis(mesh_shape, "model", shape[-1]))
    elif leaf in EXPERT_SHARDED and nd >= 3:
        e_ax = _axis(mesh_shape, "model", shape[-3])
        if e_ax:  # expert parallelism
            spec[-3] = e_ax
            spec[-2] = _axis(mesh_shape, "data", shape[-2])
        else:  # few experts: shard the expert-hidden dim instead
            hid = -1 if leaf != "w_down_e" else -2
            oth = -2 if leaf != "w_down_e" else -1
            spec[hid] = _axis(mesh_shape, "model", shape[hid])
            spec[oth] = _axis(mesh_shape, "data", shape[oth])
    elif leaf in ROW_SHARDED and nd >= 2:
        set_last2(_axis(mesh_shape, "model", shape[-2]), _axis(mesh_shape, "data", shape[-1]))
    elif leaf in COL_SHARDED and nd >= 2:
        set_last2(_axis(mesh_shape, "data", shape[-2]), _axis(mesh_shape, "model", shape[-1]))
    elif leaf == "w_r" and nd >= 3:  # sLSTM block-diagonal recurrent (H, dh, 4dh)
        spec[-1] = _axis(mesh_shape, "model", shape[-1])
    elif leaf in ("A_log", "D", "conv_w", "conv_b", "dt_bias"):
        # Mamba per-channel params: shard d_inner over model
        for i, s in enumerate(shape):
            ax = _axis(mesh_shape, "model", s)
            if ax and s >= 64:
                spec[i] = ax
                break
    # norms / biases / small vectors: replicated
    return P(*spec)


def params_pspecs(params: Any, mesh_shape: Dict[str, int]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [param_pspec(path_str(p), tuple(x.shape), mesh_shape) for p, x in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# optimizer state ---------------------------------------------------------------


def rotation_state_pspec(
    name: str, param_spec: P, shape: Tuple[int, ...], mesh_shape: Dict[str, int]
) -> P:
    """Spec for a basis-rotation state leaf given its parameter's spec."""
    if name in ("m", "v"):
        return param_spec
    batch = list(param_spec[:-2]) if len(param_spec) >= 2 else []
    batch += [None] * (len(shape) - 2 - len(batch))
    if name in ("U", "L"):  # row space (m x m): shard rows over data (FSDP)
        return P(*batch, _axis(mesh_shape, "data", shape[-2]), None)
    if name in ("V", "R"):  # column space (n x n)
        return P(*batch, _axis(mesh_shape, "data", shape[-2]), None)
    return P()


def opt_state_pspecs(opt_state_shapes: Any, params: Any, mesh_shape: Dict[str, int]) -> Any:
    """Specs for any optimizer state produced by repro.optim / repro.core.

    Works structurally: 'leaves' lists (basis rotation) map to the param
    flatten order; m/v trees mirror the param tree; queues get the param spec
    with a leading None.
    """
    pspecs = params_pspecs(params, mesh_shape)
    pflat = jax.tree_util.tree_leaves(params)
    sflat = jax.tree_util.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P))

    def rec(state):
        if state is None:
            return None
        if isinstance(state, dict):
            if "leaves" in state and isinstance(state["leaves"], list):
                out = dict(state)
                out["leaves"] = [
                    {
                        k: rotation_state_pspec(k, spec, tuple(v.shape), mesh_shape)
                        for k, v in leaf_state.items()
                    }
                    for leaf_state, spec in zip(state["leaves"], sflat)
                ]
                return out
            if "m" in state and "v" in state:
                out = dict(state)
                out["m"] = pspecs
                out["v"] = pspecs if not _is_scalar(state["v"]) else P()
                for k in state:
                    if k not in ("m", "v"):
                        out[k] = rec(state[k])
                return out
            return {k: rec(v) for k, v in state.items()}
        if isinstance(state, (list, tuple)):
            # delay queues: leading FIFO dim + param spec
            if len(state) == len(pflat):
                out = [
                    None if q is None else P(None, *spec)
                    for q, spec in zip(state, sflat)
                ]
                return out if isinstance(state, list) else tuple(out)
            t = [rec(x) for x in state]
            return t if isinstance(state, list) else tuple(t)
        return P()  # scalar leaf

    return rec(opt_state_shapes)


def _is_scalar(x) -> bool:
    return hasattr(x, "shape") and x.shape == ()


# inputs / caches ---------------------------------------------------------------


def batch_axes(mesh_shape: Dict[str, int]) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh_shape)


def tokens_pspec(batch: int, mesh_shape: Dict[str, int], extra_dims: int = 1) -> P:
    axes = batch_axes(mesh_shape)
    total = 1
    for a in axes:
        total *= mesh_shape[a]
    b_ax = axes if _div(batch, total) else None
    return P(b_ax, *([None] * extra_dims))


def generic_activation_pspec(
    shape: Tuple[int, ...], mesh_shape: Dict[str, int], batch_dim: int = 0
) -> P:
    """Shard batch over (pod,data) if divisible; largest remaining dim over model."""
    spec: List[Optional[Any]] = [None] * len(shape)
    axes = batch_axes(mesh_shape)
    total = 1
    for a in axes:
        total *= mesh_shape[a]
    if _div(shape[batch_dim], total):
        spec[batch_dim] = axes
    best, best_dim = None, -1
    for i, s in enumerate(shape):
        if i == batch_dim:
            continue
        if _div(s, mesh_shape.get("model", 0)) and s > best_dim:
            best, best_dim = i, s
    if best is not None:
        spec[best] = "model"
    return P(*spec)


def cache_pspecs(cache: Any, mesh_shape: Dict[str, int], stacked: bool = True) -> Any:
    # stacked caches have a leading superblock axis: (L, B, ...) vs (B, ...)
    bd = 1 if stacked else 0
    return jax.tree.map(
        lambda x: generic_activation_pspec(tuple(x.shape), mesh_shape, batch_dim=bd),
        cache,
    )


def make_shardings(tree_of_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
