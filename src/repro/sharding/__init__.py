from repro.sharding.rules import (
    cache_pspecs,
    generic_activation_pspec,
    make_shardings,
    opt_state_pspecs,
    param_pspec,
    params_pspecs,
    tokens_pspec,
)

__all__ = [
    "cache_pspecs",
    "generic_activation_pspec",
    "make_shardings",
    "opt_state_pspecs",
    "param_pspec",
    "params_pspecs",
    "tokens_pspec",
]
