"""Theory utilities: basis-misalignment proxies and effective delay.

The paper uses the Hessian (1,1)-norm  ||H||_{1,1} = sum_ij |H_ij|  as the
misalignment proxy (Section 2.3): for a fixed spectrum it is minimised when H
is diagonal (basis-aligned) and grows under rotation away from the eigenbasis.
Theorem E.6's stage-aware effective delay

    tau' = sqrt( sum_i C_i^2 tau_i^2 / sum_i C_i^2 )

is what the stage-aware frequency allocation minimises.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp


def norm_11(H: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.abs(H))


def rotated_hessian(
    H: jnp.ndarray, U: Optional[jnp.ndarray], V: Optional[jnp.ndarray]
) -> jnp.ndarray:
    """Hessian of f(U w~ V^T) given H over vec(W): H~ = (V (x) U)^T H (V (x) U).

    For the Kronecker-structured case used in Theorem 3.1, pass H = kron(A, B)
    with A (n x n), B (m x m); rotation matrices U (m x m), V (n x n).
    """
    mn = H.shape[0]
    if U is None and V is None:
        return H
    if U is None:
        m = mn // V.shape[0]
        U = jnp.eye(m)
    if V is None:
        n = mn // U.shape[0]
        V = jnp.eye(n)
    T = jnp.kron(V, U)
    return T.T @ H @ T


def effective_delay(c_sq: jnp.ndarray, taus: jnp.ndarray) -> jnp.ndarray:
    """tau' = sqrt( sum C_i^2 tau_i^2 / sum C_i^2 )  (Eq. 3)."""
    c_sq = c_sq.astype(jnp.float32)
    taus = taus.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(c_sq * taus**2) / jnp.maximum(jnp.sum(c_sq), 1e-30))


def stage_effective_delay(stage_c_sq: Sequence[float], num_stages: int) -> float:
    """tau' from per-stage smoothness mass, tau_k = K-1-k for k = 0..K-1."""
    c = jnp.asarray(stage_c_sq, jnp.float32)
    taus = jnp.asarray([num_stages - 1 - k for k in range(num_stages)], jnp.float32)
    return float(effective_delay(c, taus))


def estimate_norm_11(
    hvp: Callable[[jnp.ndarray], jnp.ndarray],
    dim: int,
    key: jax.Array,
    num_samples: int = 64,
) -> jnp.ndarray:
    """Estimate ||H||_{1,1} via random Cauchy quadratic forms (Xie et al. 2025).

    For v with iid standard-Cauchy entries, v^T H v is (approximately) Cauchy
    with scale ~ ||H||_{1,1}; the median of |v^T H v| estimates the scale
    (median of |Cauchy(0, s)| = s).
    """
    keys = jax.random.split(key, num_samples)

    def one(k):
        v = jax.random.cauchy(k, (dim,))
        return jnp.abs(jnp.vdot(v, hvp(v)))

    samples = jax.vmap(one)(keys)
    return jnp.median(samples)


def model_hvp(loss_fn: Callable, params, flatten_fn, unflatten_fn) -> Callable:
    """Hessian-vector product over flattened parameters."""

    def hvp(v_flat):
        v = unflatten_fn(v_flat)
        _, tangent = jax.jvp(jax.grad(loss_fn), (params,), (v,))
        return flatten_fn(tangent)

    return hvp
