"""Rotation layout: decide, per parameter leaf, whether basis rotation
applies and on which side(s).

The paper rotates MLP and attention projection matrices and excludes
embeddings, the LM head, biases, and normalisation parameters (Appendix D.2).
We generalise to "any trailing-2D projection matrix with both dims >= min_dim"
so the same rule covers MoE expert stacks, MLA low-rank factors, Mamba
projections and xLSTM projections (DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Tuple

import jax

EXCLUDE_SUBSTRINGS = (
    "embed",
    "lm_head",
    "pos_emb",
    "norm",
    "bias",
    "b_q",
    "b_k",
    "b_v",
    "b_i",
    "b_f",
    "scale",
    "A_log",
    "dt_bias",
    "conv_b",
    "frontend_proj",
)


@dataclass(frozen=True)
class LeafPlan:
    path: str
    shape: Tuple[int, ...]
    rotate: bool
    left: bool  # rotate rows (U)
    right: bool  # rotate cols (V)


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def plan_leaf(path: str, shape: Tuple[int, ...], geometry: str, min_dim: int = 8) -> LeafPlan:
    rotatable = (
        len(shape) >= 2
        and min(shape[-2], shape[-1]) >= min_dim
        and not any(s in path for s in EXCLUDE_SUBSTRINGS)
    )
    if not rotatable:
        return LeafPlan(path, shape, False, False, False)
    if geometry == "bilateral":
        return LeafPlan(path, shape, True, True, True)
    # unilateral: rotate the smaller dimension's side (cheaper, Appendix H)
    m, n = shape[-2], shape[-1]
    return LeafPlan(path, shape, True, m <= n, m > n)


def build_layout(params: Any, geometry: str, min_dim: int = 8) -> List[LeafPlan]:
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return [plan_leaf(path_str(p), tuple(x.shape), geometry, min_dim) for p, x in flat]


def rotated_fraction(params: Any, layout: List[LeafPlan]) -> float:
    """Fraction of parameters covered by rotation (coverage metric, DESIGN §5)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    tot = sum(int(x.size) for _, x in flat)
    rot = sum(int(x.size) for (_, x), pl in zip(flat, layout) if pl.rotate)
    return rot / max(tot, 1)
