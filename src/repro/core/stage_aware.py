"""Stage-aware basis-refresh frequency allocation (paper Appendix I).

Under a fixed total refresh budget, stages with larger gradient delay get
more frequent basis updates. The paper's scheduling rule, for pipeline depth
P, base frequency f0 and per-stage delay tau:

    mid = floor(P/2) - 1
    n   = mid - tau          if tau > mid
          mid + 1 - tau      if tau <= mid
    f   = floor( f0 / (1 - n/mid) )

A non-positive denominator means the stage's basis is never refreshed
(f -> infinity); this happens for the least-delayed stages, which is exactly
the theory's prescription (Theorem E.6: tau' is dominated by early-stage
misalignment mass, so spend the budget there).

``reversed_allocation`` implements the Fig. 17 ablation (budget allocated
inversely to delay), which the paper shows *degrades* convergence.
"""
from __future__ import annotations

import math
from typing import List, Sequence

NEVER = 1 << 30  # effectively "never refresh"


def stage_aware_freq(tau: int, num_stages: int, base_freq: int) -> int:
    if num_stages <= 2:
        return base_freq
    mid = num_stages // 2 - 1
    if mid <= 0:
        return base_freq
    n = (mid - tau) if tau > mid else (mid + 1 - tau)
    denom = 1.0 - n / mid
    if denom <= 0:
        return NEVER
    return max(1, int(math.floor(base_freq / denom)))


def freqs_for_delays(
    delays: Sequence[int], num_stages: int, base_freq: int, reversed_allocation: bool = False
) -> List[int]:
    """Map per-leaf delays to per-leaf refresh frequencies.

    The raw rule slightly overshoots the uniform budget; we renormalise the
    finite periods so the total refresh count matches uniform-f0 exactly
    ("the same total computational budget", paper Section 4.3).
    """
    raw = []
    for tau in delays:
        t = (num_stages - 1 - tau) if reversed_allocation else tau
        raw.append(stage_aware_freq(int(t), num_stages, base_freq))
    inv_raw = sum(1.0 / f for f in raw if f < NEVER)
    inv_uniform = len(raw) / base_freq
    if inv_raw > inv_uniform > 0:
        scale = inv_raw / inv_uniform
        raw = [f if f >= NEVER else max(1, math.ceil(f * scale)) for f in raw]
    return raw


def budget(freqs: Sequence[int], steps: int) -> float:
    """Total number of basis refreshes over a run (the conserved budget)."""
    return sum(steps / f for f in freqs if f < NEVER)
