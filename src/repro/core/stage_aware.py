"""Stage-aware basis-refresh frequency allocation (paper Appendix I).

Under a fixed total refresh budget, stages with larger gradient delay get
more frequent basis updates. The paper's scheduling rule, for pipeline depth
P, base frequency f0 and per-stage delay tau:

    mid = floor(P/2) - 1
    n   = mid - tau          if tau > mid
          mid + 1 - tau      if tau <= mid
    f   = floor( f0 / (1 - n/mid) )

A non-positive denominator means the stage's basis is never refreshed
(f -> infinity); this happens for the least-delayed stages, which is exactly
the theory's prescription (Theorem E.6: tau' is dominated by early-stage
misalignment mass, so spend the budget there).

``reversed_allocation`` implements the Fig. 17 ablation (budget allocated
inversely to delay), which the paper shows *degrades* convergence.

``StageContext`` is the staleness-metadata carrier for the optimizer stack:
one record per parameter leaf holding the leaf's gradient delay(s) — a scalar
for leaves owned wholly by one stage (the sim layout, shared/replicated
leaves) or a length-K tuple for leaves whose LEADING axis is the pipeline
stage (the SPMD stage-stacked layout). `build_optimizer` derives one from the
partition and threads it into the frequency allocation (`refresh_freqs`), the
delay-aware baselines (`delay_scales`), and the delay-FIFO wrapper
(`delay_specs`). Frequencies are budget-renormalised over the EXPANDED
canonical leaf multiset, so a stacked `(K, per, m, n)` leaf yields exactly
the per-(stage, layer) periods the per-layer sim layout would.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple, Union

NEVER = 1 << 30  # finite periods are < NEVER; f >= NEVER means "never refresh"

# Per-leaf delay specification: an int for a leaf owned by one stage, or a
# tuple of per-stage delays for a leaf whose leading axis is the stage.
DelaySpec = Union[int, Tuple[int, ...]]


def stage_aware_freq(tau: int, num_stages: int, base_freq: int) -> int:
    if num_stages <= 2:
        return base_freq
    mid = num_stages // 2 - 1
    if mid <= 0:
        return base_freq
    n = (mid - tau) if tau > mid else (mid + 1 - tau)
    denom = 1.0 - n / mid
    if denom <= 0:
        return NEVER
    return max(1, int(math.floor(base_freq / denom)))


def freqs_for_delays(
    delays: Sequence[int], num_stages: int, base_freq: int, reversed_allocation: bool = False
) -> List[int]:
    """Map per-leaf delays to per-leaf refresh frequencies.

    The raw rule slightly overshoots the uniform budget; we renormalise the
    finite periods so the total refresh count matches uniform-f0 exactly
    ("the same total computational budget", paper Section 4.3).
    """
    raw = []
    for tau in delays:
        t = (num_stages - 1 - tau) if reversed_allocation else tau
        raw.append(stage_aware_freq(int(t), num_stages, base_freq))
    inv_raw = sum(1.0 / f for f in raw if f < NEVER)
    inv_uniform = len(raw) / base_freq
    if inv_raw > inv_uniform > 0:
        scale = inv_raw / inv_uniform
        raw = [f if f >= NEVER else max(1, math.ceil(f * scale)) for f in raw]
    return raw


def budget(freqs: Sequence[int], steps: int) -> float:
    """Total number of basis refreshes over a run (the conserved budget)."""
    return sum(steps / f for f in freqs if f < NEVER)


@dataclass(frozen=True)
class StageContext:
    """Per-leaf staleness metadata for one parameter layout.

    ``delays[i]`` is leaf i's gradient delay: an int (leaf lives wholly on
    one stage) or a length-``num_stages`` tuple (leaf's leading axis is the
    stage). ``repeats[i]`` is how many canonical per-layer leaves each delay
    entry stands for — 1 for sim/shared leaves, layers-per-stage for stacked
    block leaves — so budget renormalisation sees the same leaf multiset the
    per-layer sim layout would.
    """

    num_stages: int
    delays: Tuple[DelaySpec, ...]
    repeats: Tuple[int, ...]
    # Uniform extra delay from an asynchronous DATA axis: with a deferred
    # cross-replica reduction the gradient applied at step t is the D-step-old
    # global reduction, on top of the per-stage pipeline delay. Every consumer
    # of delay *values* (refresh_freqs, delay_scales) sees the TOTAL delay
    # tau_k + data_delay; `delay_specs` stays pipeline-only because the FIFO
    # wrapper models the physical per-stage stash — the data-axis delay is
    # imposed by the engine's reduction FIFO, not by deepening the stage FIFO.
    data_delay: int = 0

    def __post_init__(self):
        assert len(self.delays) == len(self.repeats)
        assert self.data_delay >= 0

    def _expanded_delays(self) -> List[int]:
        out: List[int] = []
        for d, r in zip(self.delays, self.repeats):
            taus = d if isinstance(d, tuple) else (d,)
            out.extend(int(t) + self.data_delay for t in taus for _ in range(r))
        return out

    def refresh_freqs(
        self, base_freq: int, reversed_allocation: bool = False
    ) -> List[Union[int, Tuple[int, ...]]]:
        """Per-leaf refresh-period specs mirroring ``delays``' shapes.

        The budget is renormalised over the expanded canonical multiset, so
        the period assigned to delay tau is identical whether tau arrives as
        a scalar (sim leaf) or as one slot of a stacked leaf's tuple. With a
        ``data_delay``, the allocation runs on the TOTAL per-leaf delay
        tau + data_delay — under async data parallelism every stage is that
        much staler, and the budget shifts accordingly.
        """
        expanded = self._expanded_delays()
        flat = freqs_for_delays(
            expanded, self.num_stages, base_freq, reversed_allocation
        )
        lut = dict(zip(expanded, flat))
        out: List[Union[int, Tuple[int, ...]]] = []
        for d in self.delays:
            if isinstance(d, tuple):
                out.append(tuple(lut[int(t) + self.data_delay] for t in d))
            else:
                out.append(lut[int(d) + self.data_delay])
        return out

    def delay_specs(self) -> List[Union[int, str]]:
        """Per-leaf specs for the delay-FIFO wrappers: ``"stage"`` for
        stage-stacked leaves, the scalar delay otherwise. PIPELINE delay
        only — ``data_delay`` is imposed upstream (the engine's deferred
        reduction FIFO), so it must not deepen the stage FIFO."""
        return ["stage" if isinstance(d, tuple) else int(d) for d in self.delays]

    def delay_scales(self, params) -> "object":
        """Pytree matching ``params`` of per-leaf TOTAL delay values
        (pipeline tau + ``data_delay``), broadcastable over each leaf: scalar
        ints for single-stage leaves, a ``(K, 1, ..., 1)`` fp32 array over
        the leading stage axis for stacked leaves. Consumed by the
        delay-aware baselines (PipeDream-LR, Nesterov async-PP)."""
        import jax
        import jax.numpy as jnp

        flat, treedef = jax.tree_util.tree_flatten(params)
        assert len(flat) == len(self.delays), "context must match leaf count"
        leaves = []
        for p, d in zip(flat, self.delays):
            if isinstance(d, tuple):
                assert p.shape[0] == len(d), (
                    f"stacked leaf leading axis {p.shape} != {len(d)} stages"
                )
                arr = jnp.asarray(
                    [t + self.data_delay for t in d], jnp.float32
                ).reshape((len(d),) + (1,) * (len(p.shape) - 1))
                leaves.append(arr)
            else:
                leaves.append(int(d) + self.data_delay)
        return jax.tree_util.tree_unflatten(treedef, leaves)
