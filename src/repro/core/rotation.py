"""Eigenbasis estimation and rotation primitives (paper Algorithm 2).

All math is fp32 regardless of parameter dtype. Every function broadcasts
over arbitrary leading (stacked-layer / expert) dimensions — `jnp.linalg.qr`
and einsum are batched, so a scanned parameter stack of shape (L, E, m, n)
rotates with a single call.

The estimation taxonomy:
  source   S = "2nd": EMA Kronecker factors L = EMA[G G^T], R = EMA[G^T G]
           S = "1st": momentum outer products M M^T / M^T M (no extra state)
  geometry G = "bilateral": rotate both sides (U and V)
           G = "unilateral": rotate only the smaller dimension's side

One power-iteration step + QR per refresh (Wang et al. 2024), with a
deterministic sign convention so bases are reproducible.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def power_qr(A: jnp.ndarray, Q: jnp.ndarray) -> jnp.ndarray:
    """One power-iteration step followed by QR: Q' = qr(A @ Q).Q.

    A: (..., n, n) symmetric PSD; Q: (..., n, k) orthonormal columns.
    """
    Z = jnp.einsum("...ij,...jk->...ik", A.astype(jnp.float32), Q.astype(jnp.float32))
    Qn, R = jnp.linalg.qr(Z)
    # fix signs (QR is unique only up to column signs): diag(R) >= 0
    sign = jnp.sign(jnp.diagonal(R, axis1=-2, axis2=-1))
    sign = jnp.where(sign == 0, 1.0, sign)
    return Qn * sign[..., None, :]


def batched_eye(n: int, batch_shape: Tuple[int, ...]) -> jnp.ndarray:
    eye = jnp.eye(n, dtype=jnp.float32)
    return jnp.broadcast_to(eye, batch_shape + (n, n))


def gram_left(g: jnp.ndarray) -> jnp.ndarray:
    """(..., m, n) -> (..., m, m) = G @ G^T."""
    g = g.astype(jnp.float32)
    return jnp.einsum("...ik,...jk->...ij", g, g)


def gram_right(g: jnp.ndarray) -> jnp.ndarray:
    """(..., m, n) -> (..., n, n) = G^T @ G."""
    g = g.astype(jnp.float32)
    return jnp.einsum("...ki,...kj->...ij", g, g)


def rotate(
    x: jnp.ndarray, U: Optional[jnp.ndarray], V: Optional[jnp.ndarray]
) -> jnp.ndarray:
    """x_tilde = U^T x V (missing side = identity)."""
    x = x.astype(jnp.float32)
    if U is not None:
        x = jnp.einsum("...ji,...jk->...ik", U, x)
    if V is not None:
        x = jnp.einsum("...ij,...jk->...ik", x, V)
    return x


def unrotate(
    x: jnp.ndarray, U: Optional[jnp.ndarray], V: Optional[jnp.ndarray]
) -> jnp.ndarray:
    """x = U x_tilde V^T (missing side = identity)."""
    x = x.astype(jnp.float32)
    if U is not None:
        x = jnp.einsum("...ij,...jk->...ik", U, x)
    if V is not None:
        x = jnp.einsum("...ik,...jk->...ij", x, V)
    return x


def refresh_basis(
    g: jnp.ndarray,
    m: jnp.ndarray,
    U: Optional[jnp.ndarray],
    V: Optional[jnp.ndarray],
    L: Optional[jnp.ndarray],
    R: Optional[jnp.ndarray],
    source: str,
    beta2: float,
):
    """One Eigenbasis-Estimation step (Algorithm 2). Returns (U, V, L, R)."""
    if source == "2nd":
        if U is not None:
            L = beta2 * L + (1 - beta2) * gram_left(g)
            U = power_qr(L, U)
        if V is not None:
            R = beta2 * R + (1 - beta2) * gram_right(g)
            V = power_qr(R, V)
    else:  # 1st: reuse the momentum buffer, no dedicated Fisher state
        if U is not None:
            U = power_qr(gram_left(m), U)
        if V is not None:
            V = power_qr(gram_right(m), V)
    return U, V, L, R
