# The paper's primary contribution: Adam with Basis Rotation for
# asynchronous pipeline parallelism (Algorithms 1-2 + stage-aware scheduling).
from repro.core.basis_rotation import basis_rotation_adam
from repro.core.layout import LeafPlan, build_layout, rotated_fraction
from repro.core.rotation import power_qr, refresh_basis, rotate, unrotate
from repro.core.stage_aware import (
    NEVER,
    StageContext,
    freqs_for_delays,
    stage_aware_freq,
)
from repro.core.theory import effective_delay, norm_11, rotated_hessian

__all__ = [
    "basis_rotation_adam",
    "LeafPlan",
    "build_layout",
    "rotated_fraction",
    "power_qr",
    "refresh_basis",
    "rotate",
    "unrotate",
    "freqs_for_delays",
    "stage_aware_freq",
    "NEVER",
    "StageContext",
    "effective_delay",
    "norm_11",
    "rotated_hessian",
]
