"""Adam with Basis Rotation (paper Algorithm 1) — the core contribution.

Per rotatable weight matrix W (m x n):
    G_t  <- grad                                  (original space)
    M_t  <- b1 M_{t-1} + (1-b1) G_t               (original space, Appendix G)
    if t % freq == 0:  U,V <- Eigenbasis-Estimation(G_t, M_t, U, V)
    G~ <- U^T G V ; M~ <- U^T M V                 (rotate at use time)
    V~_t <- b2 V~_{t-1} + (1-b2) G~^2             (rotated second moment)
    W <- W - lr * U ( M~ / sqrt(V~ + eps) ) V^T

Non-rotatable leaves (embeddings, norms, biases, 1-D params) fall back to
plain Adam — exactly the paper's setup.

State is a flat list of per-leaf dicts (ordered like
``jax.tree_util.tree_flatten(params)``), which keeps the whole thing a plain
pytree: shardable under pjit, delayable under the FIFO wrapper, and
checkpointable with no special cases.

``freq``: the refresh-period spec. A scalar int applies one period to every
leaf; a per-leaf sequence (stage-aware allocation, `repro.core.stage_aware`)
gives each leaf its own entry, where an entry is either

  * an int — uniform period for the whole leaf (the sim layout's per-layer
    leaves), refreshed via a single ``lax.cond``; or
  * a tuple of K ints — per-stage periods over the leaf's LEADING stage axis
    (the SPMD stage-stacked ``(K, per, m, n)`` layout), refreshed through a
    vectorized per-stage mask: ``refresh_basis`` runs batched over the stage
    axis and ``jnp.where`` keeps stage k's old basis unless
    ``step % freq[k] == 0``.

A period <= 0 or >= ``stage_aware.NEVER`` means "never refresh" (the basis
stays at identity unless warm-started).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.layout import LeafPlan, build_layout
from repro.core.rotation import (
    batched_eye,
    refresh_basis,
    rotate,
    unrotate,
)
from repro.core.stage_aware import NEVER
from repro.optim.base import Optimizer, Schedule, bias_correction

FreqSpec = Union[int, Tuple[int, ...]]


def _init_leaf(p: jnp.ndarray, plan: LeafPlan, source: str) -> dict:
    st = {
        "m": jnp.zeros(p.shape, jnp.float32),
        "v": jnp.zeros(p.shape, jnp.float32),
    }
    if not plan.rotate:
        return st
    batch = p.shape[:-2]
    m, n = p.shape[-2], p.shape[-1]
    if plan.left:
        st["U"] = batched_eye(m, batch)
        if source == "2nd":
            st["L"] = jnp.zeros(batch + (m, m), jnp.float32)
    if plan.right:
        st["V"] = batched_eye(n, batch)
        if source == "2nd":
            st["R"] = jnp.zeros(batch + (n, n), jnp.float32)
    return st


def _refresh_ops(g, m, ops, freq: FreqSpec, step, source: str, beta2: float):
    """Apply the (possibly per-stage) refresh schedule to (U, V, L, R).

    Scalar periods keep the single ``lax.cond`` on ``step % f == 0`` — the
    sim backend's bit-for-bit path. Tuple periods vectorize: the refresh runs
    batched over the leaf's leading stage axis and a per-stage mask selects,
    per stage, the refreshed or the previous basis (and Fisher EMA — a
    non-refreshing stage must not advance L/R either, matching the cond).
    """

    def do_refresh(o):
        Uo, Vo, Lo, Ro = o
        return refresh_basis(g, m, Uo, Vo, Lo, Ro, source, beta2)

    if isinstance(freq, tuple):
        K = len(freq)
        assert g.shape[0] == K, (
            f"per-stage freqs {freq} need a leading stage axis of {K}, "
            f"got leaf shape {g.shape}"
        )
        if not any(0 < f < NEVER for f in freq):
            return ops
        farr = jnp.asarray(freq, jnp.int32)
        live = jnp.asarray([0 < f < NEVER for f in freq])
        mask = live & (step % jnp.maximum(farr, 1) == 0)  # (K,)

        def masked_refresh(o):
            new = do_refresh(o)

            def sel(n, old):
                if old is None:
                    return None
                return jnp.where(mask.reshape((K,) + (1,) * (old.ndim - 1)), n, old)

            return tuple(sel(n, old) for n, old in zip(new, o))

        return jax.lax.cond(jnp.any(mask), masked_refresh, lambda o: o, ops)

    if 0 < freq < NEVER:
        return jax.lax.cond(step % freq == 0, do_refresh, lambda o: o, ops)
    return ops


def basis_rotation_adam(
    schedule: Schedule,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    source: str = "2nd",
    geometry: str = "bilateral",
    freq: Union[int, Sequence[FreqSpec]] = 10,
    weight_decay: float = 0.0,
    min_dim: int = 8,
    use_kernels: bool = False,
) -> Optimizer:
    assert source in ("1st", "2nd") and geometry in ("unilateral", "bilateral")

    if use_kernels:
        from repro.kernels import ops as kops
    else:
        kops = None

    def init(params):
        layout = build_layout(params, geometry, min_dim)
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        return {"leaves": [_init_leaf(x, pl, source) for (_, x), pl in zip(flat, layout)]}

    def update(grads, state, params, step, aux=None):
        layout = build_layout(params, geometry, min_dim)
        if isinstance(freq, int):
            freqs: List[FreqSpec] = [freq] * len(layout)
        else:
            freqs = [tuple(f) if isinstance(f, (tuple, list)) else int(f)
                     for f in freq]
            assert len(freqs) == len(layout), "freq list must match leaf count"
        lr = schedule(step)
        bc1, bc2 = bias_correction(beta1, step), bias_correction(beta2, step)

        gflat, gdef = jax.tree_util.tree_flatten(grads)
        new_leaves = []
        updates = []
        for g, st, plan, f in zip(gflat, state["leaves"], layout, freqs):
            g = g.astype(jnp.float32)
            m = beta1 * st["m"] + (1 - beta1) * g
            nst = dict(st)
            nst["m"] = m

            if plan.rotate:
                U, V, L, R = _refresh_ops(
                    g, m,
                    (st.get("U"), st.get("V"), st.get("L"), st.get("R")),
                    f, step, source, beta2,
                )
                if kops is not None:
                    g_rot = kops.two_sided_rotate(g, U, V, transpose=True)
                    m_rot = kops.two_sided_rotate(m, U, V, transpose=True)
                    step_rot, v = kops.adam_scale(
                        g_rot, m_rot, st["v"], beta2, eps, bc1, bc2
                    )
                    upd = -lr * kops.two_sided_rotate(step_rot, U, V, transpose=False)
                else:
                    g_rot = rotate(g, U, V)
                    m_rot = rotate(m, U, V)
                    v = beta2 * st["v"] + (1 - beta2) * jnp.square(g_rot)
                    step_rot = (m_rot / bc1) / (jnp.sqrt(v / bc2) + eps)
                    upd = -lr * unrotate(step_rot, U, V)
                nst["v"] = v
                if U is not None:
                    nst["U"] = U
                if V is not None:
                    nst["V"] = V
                if L is not None:
                    nst["L"] = L
                if R is not None:
                    nst["R"] = R
            else:
                v = beta2 * st["v"] + (1 - beta2) * jnp.square(g)
                upd = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
                nst["v"] = v

            updates.append(upd)
            new_leaves.append(nst)

        if weight_decay:
            # decoupled weight decay on matrices only (norms/biases exempt)
            pflat, _ = jax.tree_util.tree_flatten(params)
            updates = [
                u - lr * weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else u
                for u, p in zip(updates, pflat)
            ]
        return jax.tree_util.tree_unflatten(gdef, updates), {"leaves": new_leaves}

    return Optimizer(init, update)
