"""Adam with Basis Rotation (paper Algorithm 1) — the core contribution.

Per rotatable weight matrix W (m x n):
    G_t  <- grad                                  (original space)
    M_t  <- b1 M_{t-1} + (1-b1) G_t               (original space, Appendix G)
    if t % freq == 0:  U,V <- Eigenbasis-Estimation(G_t, M_t, U, V)
    G~ <- U^T G V ; M~ <- U^T M V                 (rotate at use time)
    V~_t <- b2 V~_{t-1} + (1-b2) G~^2             (rotated second moment)
    W <- W - lr * U ( M~ / sqrt(V~ + eps) ) V^T

Non-rotatable leaves (embeddings, norms, biases, 1-D params) fall back to
plain Adam — exactly the paper's setup.

State is a flat list of per-leaf dicts (ordered like
``jax.tree_util.tree_flatten(params)``), which keeps the whole thing a plain
pytree: shardable under pjit, delayable under the FIFO wrapper, and
checkpointable with no special cases.

``freqs``: either a scalar int (uniform refresh period) or a list of ints per
leaf (stage-aware allocation, `repro.core.stage_aware`). A freq <= 0 means
"never refresh" (the basis stays at identity unless warm-started).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.layout import LeafPlan, build_layout
from repro.core.rotation import (
    batched_eye,
    refresh_basis,
    rotate,
    unrotate,
)
from repro.optim.base import Optimizer, Schedule, bias_correction


def _init_leaf(p: jnp.ndarray, plan: LeafPlan, source: str) -> dict:
    st = {
        "m": jnp.zeros(p.shape, jnp.float32),
        "v": jnp.zeros(p.shape, jnp.float32),
    }
    if not plan.rotate:
        return st
    batch = p.shape[:-2]
    m, n = p.shape[-2], p.shape[-1]
    if plan.left:
        st["U"] = batched_eye(m, batch)
        if source == "2nd":
            st["L"] = jnp.zeros(batch + (m, m), jnp.float32)
    if plan.right:
        st["V"] = batched_eye(n, batch)
        if source == "2nd":
            st["R"] = jnp.zeros(batch + (n, n), jnp.float32)
    return st


def basis_rotation_adam(
    schedule: Schedule,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    source: str = "2nd",
    geometry: str = "bilateral",
    freq: Union[int, Sequence[int]] = 10,
    weight_decay: float = 0.0,
    min_dim: int = 8,
    use_kernels: bool = False,
) -> Optimizer:
    assert source in ("1st", "2nd") and geometry in ("unilateral", "bilateral")

    if use_kernels:
        from repro.kernels import ops as kops
    else:
        kops = None

    def init(params):
        layout = build_layout(params, geometry, min_dim)
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        return {"leaves": [_init_leaf(x, pl, source) for (_, x), pl in zip(flat, layout)]}

    def update(grads, state, params, step, aux=None):
        layout = build_layout(params, geometry, min_dim)
        if isinstance(freq, int):
            freqs: List[int] = [freq] * len(layout)
        else:
            freqs = list(freq)
            assert len(freqs) == len(layout), "freq list must match leaf count"
        lr = schedule(step)
        bc1, bc2 = bias_correction(beta1, step), bias_correction(beta2, step)

        gflat, gdef = jax.tree_util.tree_flatten(grads)
        new_leaves = []
        updates = []
        for g, st, plan, f in zip(gflat, state["leaves"], layout, freqs):
            g = g.astype(jnp.float32)
            m = beta1 * st["m"] + (1 - beta1) * g
            nst = dict(st)
            nst["m"] = m

            if plan.rotate:
                U, V = st.get("U"), st.get("V")
                L, R = st.get("L"), st.get("R")
                if f > 0:

                    def do_refresh(ops):
                        Uo, Vo, Lo, Ro = ops
                        return refresh_basis(g, m, Uo, Vo, Lo, Ro, source, beta2)

                    def no_refresh(ops):
                        return ops

                    U, V, L, R = jax.lax.cond(
                        step % f == 0, do_refresh, no_refresh, (U, V, L, R)
                    )
                if kops is not None:
                    g_rot = kops.two_sided_rotate(g, U, V, transpose=True)
                    m_rot = kops.two_sided_rotate(m, U, V, transpose=True)
                else:
                    g_rot = rotate(g, U, V)
                    m_rot = rotate(m, U, V)
                v = beta2 * st["v"] + (1 - beta2) * jnp.square(g_rot)
                step_rot = (m_rot / bc1) / (jnp.sqrt(v / bc2) + eps)
                if kops is not None:
                    upd = -lr * kops.two_sided_rotate(step_rot, U, V, transpose=False)
                else:
                    upd = -lr * unrotate(step_rot, U, V)
                nst["v"] = v
                if U is not None:
                    nst["U"] = U
                if V is not None:
                    nst["V"] = V
                if L is not None:
                    nst["L"] = L
                if R is not None:
                    nst["R"] = R
            else:
                v = beta2 * st["v"] + (1 - beta2) * jnp.square(g)
                upd = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
                nst["v"] = v

            updates.append(upd)
            new_leaves.append(nst)

        if weight_decay:
            # decoupled weight decay on matrices only (norms/biases exempt)
            pflat, _ = jax.tree_util.tree_flatten(params)
            updates = [
                u - lr * weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else u
                for u, p in zip(updates, pflat)
            ]
        return jax.tree_util.tree_unflatten(gdef, updates), {"leaves": new_leaves}

    return Optimizer(init, update)
