from repro.pipeline.delay import delayed_optimizer, max_delay
from repro.pipeline.partition import (
    delay_tree,
    layer_to_stage,
    leaf_delays,
    leaf_stages,
    stage_context_for_stacked,
    stage_context_for_tree,
)
from repro.pipeline.simulate import (
    make_sim_train_step,
    predict_weights,
    run_sim_training,
)

__all__ = [
    "delayed_optimizer",
    "max_delay",
    "delay_tree",
    "layer_to_stage",
    "leaf_delays",
    "leaf_stages",
    "stage_context_for_stacked",
    "stage_context_for_tree",
    "make_sim_train_step",
    "predict_weights",
    "run_sim_training",
]
