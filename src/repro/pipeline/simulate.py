"""Single-program simulation of asynchronous pipeline-parallel training.

This is the deterministic equivalent of the paper's virtual-stage setup
(Appendix D.2): the delay pattern of a K-stage PipeDream pipeline is imposed
exactly — per-stage gradient delay tau_k = K-1-k via the FIFO wrapper — while
compute runs as one jitted program. Convergence behaviour (the paper's
experimental subject) depends only on the delay pattern, so this reproduces
Figures 2, 5-10 faithfully on CPU at reduced scale.

Modes:
  * weight stashing (default): gradient FIFO == stashed-weight semantics.
  * weight prediction (PipeMare, Yang et al. 2021): the forward pass runs on
    weights extrapolated tau steps ahead using the optimizer's momentum.
  * no stashing (Gaunt et al. 2017): forward activations and backward
    linearisation use *different* weight versions per stage — the gradient is
    not the gradient of any single point. Implemented with a per-block
    custom_vjp taking two parameter versions.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import loss_fn
from repro.optim.base import Optimizer, apply_updates, clip_by_global_norm


# ---------------------------------------------------------------------------
# PipeMare-style weight prediction
# ---------------------------------------------------------------------------


def _find_moments(opt_state: Any) -> Optional[Dict]:
    """Locate Adam-style (m, v) in possibly-wrapped optimizer state."""
    if isinstance(opt_state, dict):
        if "m" in opt_state and "v" in opt_state:
            return {"m": opt_state["m"], "v": opt_state["v"]}
        if "inner" in opt_state:
            return _find_moments(opt_state["inner"])
        if "leaves" in opt_state:
            return {"leaves": opt_state["leaves"]}
    return None


def predict_weights(params, opt_state, delays_tree, lr, eps: float = 1e-8):
    """w_hat = w - lr * tau * m / (sqrt(v) + eps): extrapolate tau steps ahead.

    For basis-rotation state the momentum `m` lives in the ORIGINAL space but
    the second moment `v` lives in the ROTATED space, so the Adam-style ratio
    must be formed there: rotate m into the eigenbasis, divide by sqrt(v),
    and rotate the step back — mirroring the optimizer's own update direction.
    (Dividing original-space m by rotated-space v elementwise mixes bases and
    produces an incoherent prediction.)
    """
    mo = _find_moments(opt_state)
    if mo is None:
        return params
    if "leaves" in mo:  # basis-rotation state: flat leaf list
        from repro.core.rotation import rotate, unrotate

        flat, treedef = jax.tree_util.tree_flatten(params)
        dflat, _ = jax.tree_util.tree_flatten(delays_tree)
        new = []
        for p, st, d in zip(flat, mo["leaves"], dflat):
            if d <= 0:
                new.append(p)
                continue
            U, V = st.get("U"), st.get("V")
            if U is not None or V is not None:
                m_rot = rotate(st["m"], U, V)
                step = unrotate(m_rot / (jnp.sqrt(st["v"]) + eps), U, V)
            else:
                step = st["m"] / (jnp.sqrt(st["v"]) + eps)
            new.append((p - lr * d * step).astype(p.dtype))
        return jax.tree_util.tree_unflatten(treedef, new)
    return jax.tree.map(
        lambda p, m, v, d: (p - lr * d * m / (jnp.sqrt(v) + eps)).astype(p.dtype),
        params,
        mo["m"],
        mo["v"],
        delays_tree,
    )


# ---------------------------------------------------------------------------
# No-stash two-version gradients
# ---------------------------------------------------------------------------


def make_two_version_loss(cfg: ModelConfig) -> Callable:
    """loss(params_fwd, params_bwd, batch): activations from params_fwd,
    backward linearisation at params_bwd. Differentiate w.r.t. arg 1."""
    from repro.models.model import _embed, _logits, _run_blocks_train, cross_entropy
    from repro.models.layers import apply_norm
    from repro.models.transformer import block_train

    assert not cfg.scan_layers, "no-stash simulation requires scan_layers=False"

    @jax.custom_vjp
    def block2w(pf, pb, x, l):
        y, _ = block_train(pf, x, cfg, cfg.pattern[l % len(cfg.pattern)])
        return y

    def block2w_fwd(pf, pb, x, l):
        y, _ = block_train(pf, x, cfg, cfg.pattern[l % len(cfg.pattern)])
        return y, (pf, pb, x, l)

    def block2w_bwd(res, ct):
        pf, pb, x, l = res
        # linearise at the *backward-time* weights (version mismatch)
        _, vjp = jax.vjp(
            lambda p, xx: block_train(p, xx, cfg, cfg.pattern[l % len(cfg.pattern)])[0],
            pb,
            x,
        )
        dpb, dx = vjp(ct)
        dpf = jax.tree.map(jnp.zeros_like, pf)
        return dpf, dpb, dx, None

    block2w.defvjp(block2w_fwd, block2w_bwd)

    def loss2w(params_bwd, params_fwd, batch):
        x = _embed(params_bwd, cfg, batch["tokens"])
        if cfg.learnable_pos_emb:
            x = x + params_bwd["pos_emb"][: x.shape[1]].astype(x.dtype)
        for l in range(cfg.num_layers):
            x = block2w(params_fwd["blocks"][l], params_bwd["blocks"][l], x, l)
        x = apply_norm(params_bwd["final_norm"], x)
        logits = _logits(params_bwd, cfg, x)
        return cross_entropy(logits, batch["labels"])

    return loss2w


# ---------------------------------------------------------------------------
# Train-step factory + driver
# ---------------------------------------------------------------------------


def make_sim_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    grad_clip: float = 1.0,
    weight_prediction: bool = False,
    delays_tree=None,
    schedule=None,
    no_stash: bool = False,
):
    loss2w = make_two_version_loss(cfg) if no_stash else None

    def train_step(params, opt_state, fwd_hist, batch, step):
        fwd_params = params
        if weight_prediction and delays_tree is not None and schedule is not None:
            fwd_params = predict_weights(params, opt_state, delays_tree, schedule(step))

        if no_stash:
            # forward runs on stage-stale snapshots, backward on current
            # params — the version-mismatch pathology of stash-less PipeDream
            loss, grads = jax.value_and_grad(loss2w)(params, fwd_hist, batch)
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                fwd_params, cfg, batch
            )
        if grad_clip:
            grads = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, loss, metrics

    # NOTE: no buffer donation — the simulator is CPU-scale and callers often
    # reuse the same initial params across optimizer comparisons.
    return jax.jit(train_step)


def stale_forward_params(history, params, delays_tree):
    """Per-leaf stale parameter tree: the leaf on the stage with delay tau
    comes from the snapshot tau steps ago (its forward-time version)."""
    if delays_tree is None or not history:
        return params
    pflat, treedef = jax.tree_util.tree_flatten(params)
    dflat = jax.tree_util.tree_leaves(delays_tree)
    hists = [jax.tree_util.tree_leaves(h) for h in history]  # oldest..newest
    out = []
    for i, (p, d) in enumerate(zip(pflat, dflat)):
        # history[-1] == current params (appended after the step), so the
        # version from d steps ago lives at history[-1-d]
        age = min(int(d), len(hists) - 1)
        out.append(hists[-1 - age][i] if age > 0 else p)
    return jax.tree_util.tree_unflatten(treedef, out)


def run_sim_training(
    cfg: ModelConfig,
    optimizer: Optimizer,
    data_iter,
    steps: int,
    grad_clip: float = 1.0,
    key=None,
    params=None,
    weight_prediction: bool = False,
    delays_tree=None,
    schedule=None,
    no_stash: bool = False,
    log_every: int = 0,
) -> Tuple[Any, Any, List[float]]:
    """Run `steps` simulated-async steps; returns (params, opt_state, losses).

    Thin wrapper over the unified engine loop (`repro.engine`): builds a
    `SimEngine` and drives it with `run_loop` — the step sequence (and hence
    the fixed-seed loss curve) is unchanged from the pre-engine driver.
    """
    from repro.engine.loop import LoopConfig, run_loop
    from repro.engine.sim import SimEngine

    engine = SimEngine(
        cfg, optimizer, grad_clip, weight_prediction, delays_tree, schedule, no_stash
    )
    state = engine.init_state(params=params, key=key)
    state, losses = run_loop(
        engine, data_iter, LoopConfig(steps=steps, log_every=log_every), state=state
    )
    return state.params, state.opt_state, losses
