"""Deterministic gradient-staleness wrapper (weight-stashing semantics).

In PipeDream with weight stashing, the gradient applied at step t on stage k
was computed — forward AND backward — from the weights that stage held at
step t - tau_k. A per-leaf FIFO of gradients of depth tau_k reproduces this
exactly: push the fresh gradient, pop and apply the one from tau_k steps ago.
During the first tau_k steps the queue yields zeros — the pipeline warm-up,
where no update has arrived yet.

This is the deterministic, single-program equivalent of the paper's
virtual-stage simulation (Appendix D.2) and what the convergence benchmarks
run on CPU. In the distributed runtime the same wrapper runs sharded: each
stage's queue lives on that stage's devices, which is precisely weight
stashing's memory footprint (linear in pipeline depth — paper Section 4.3).

``store_params=True`` additionally queues parameter snapshots so
delay-compensation (Zheng et al. 2017) can access w_{t-tau}.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def _push_pop(queue: jnp.ndarray, fresh: jnp.ndarray):
    """queue: (tau, ...). Returns (oldest, new_queue)."""
    oldest = queue[0]
    new_q = jnp.concatenate([queue[1:], fresh[None].astype(queue.dtype)], axis=0)
    return oldest, new_q


def delayed_optimizer(
    inner: Optimizer,
    delays: Sequence[int],
    store_params: bool = False,
) -> Optimizer:
    """Wrap ``inner`` so each leaf's gradient is applied tau leaf-steps late.

    ``delays``: per-leaf ints ordered like ``jax.tree_util.tree_flatten``.
    """
    delays = [int(d) for d in delays]

    def init(params):
        flat, _ = jax.tree_util.tree_flatten(params)
        assert len(flat) == len(delays), "delay list must match leaf count"
        gq = [
            jnp.zeros((d,) + p.shape, jnp.float32) if d > 0 else None
            for p, d in zip(flat, delays)
        ]
        state = {"inner": inner.init(params), "grad_q": gq}
        if store_params:
            state["param_q"] = [
                jnp.stack([p.astype(jnp.float32)] * d) if d > 0 else None
                for p, d in zip(flat, delays)
            ]
        return state

    def update(grads, state, params, step, aux=None):
        gflat, gdef = jax.tree_util.tree_flatten(grads)
        delayed, new_gq = [], []
        for g, q, d in zip(gflat, state["grad_q"], delays):
            if d == 0:
                delayed.append(g)
                new_gq.append(None)
            else:
                old, nq = _push_pop(q, g)
                delayed.append(old)
                new_gq.append(nq)
        delayed_tree = jax.tree_util.tree_unflatten(gdef, delayed)

        inner_aux = dict(aux or {})
        new_state = {"grad_q": new_gq}
        if store_params:
            pflat, _ = jax.tree_util.tree_flatten(params)
            stale, new_pq = [], []
            for p, q, d in zip(pflat, state["param_q"], delays):
                if d == 0:
                    stale.append(p)
                    new_pq.append(None)
                else:
                    old, nq = _push_pop(q, p)
                    stale.append(old)
                    new_pq.append(nq)
            inner_aux["stale_params"] = jax.tree_util.tree_unflatten(gdef, stale)
            new_state["param_q"] = new_pq

        try:
            updates, inner_state = inner.update(
                delayed_tree, state["inner"], params, step, aux=inner_aux or None
            )
        except TypeError:
            updates, inner_state = inner.update(delayed_tree, state["inner"], params, step)
        new_state["inner"] = inner_state
        return updates, new_state

    return Optimizer(init, update)


def stage_delayed_optimizer(
    inner: Optimizer,
    specs: Sequence,
    num_stages: int,
    store_params: bool = False,
    extra_param_delay: int = 0,
) -> Optimizer:
    """Delay wrapper for the SPMD stage-stacked parameter layout.

    ``specs`` is per-leaf (ordered like ``tree_flatten``): either an int delay
    (shared/replicated leaves — identical to ``delayed_optimizer``) or the
    string ``"stage"`` for leaves whose LEADING axis is the pipeline stage
    (``StageContext.delay_specs`` produces exactly this list).

    For a ``"stage"`` leaf of shape (K, ...), a FIFO of depth K-1 holds the
    last K-1 full gradients; stage k pops the one from tau_k = K-1-k steps
    ago, which after the push/pop algebra is exactly the DIAGONAL read
    ``queue[k][k]`` (the last stage uses the fresh gradient). Sharded over the
    `stage` mesh axis, each device materialises only its own (K-1, 1, ...)
    queue slice — weight stashing's linear-in-depth footprint (paper §4.3).

    During warm-up (t < tau_k) stage k receives zeros, matching the per-leaf
    FIFO semantics of the simulator.

    ``store_params=True`` additionally queues parameter snapshots with the
    same diagonal read, so stage k sees its own w_{t-tau_k} — the stale
    weight version delay compensation linearises around
    (``aux={"stale_params": ...}``). Param queues warm-start with the current
    parameters (during warm-up the "stale" weights ARE the initial weights),
    mirroring ``delayed_optimizer``.

    ``extra_param_delay=D`` deepens only the PARAM queues by D slots, so the
    stale snapshot stage k reads is w_{t-(tau_k+D)} — the total staleness
    when the engine additionally applies a D-step-old deferred data-axis
    reduction to every gradient (async data mode). The grad queues stay at
    pipeline depth: the data-axis delay on gradients is imposed upstream by
    the engine's reduction FIFO, not here.
    """
    K = int(num_stages)
    E = int(extra_param_delay)
    specs = list(specs)

    def _q_shape(p, s):
        if s == "stage":
            return jnp.zeros((K - 1,) + p.shape, jnp.float32) if K > 1 else None
        return jnp.zeros((int(s),) + p.shape, jnp.float32) if int(s) > 0 else None

    def _p_queue(p, s):
        depth = ((K - 1) if s == "stage" else int(s)) + E
        if depth <= 0:
            return None
        return jnp.broadcast_to(p.astype(jnp.float32), (depth,) + p.shape)

    def _pop_push(q, fresh, s):
        """(stale, new_queue) under spec ``s``; fresh is the step's value."""
        if s == "stage":
            # pop: stage k reads the entry pushed K-1-k steps ago (row k),
            # restricted to its own stage slice -> queue diagonal; one
            # gather keeps the traced step O(1) in K
            idx = jnp.arange(K - 1)
            diag = q[idx, idx]
            stale = jnp.concatenate([diag, fresh[K - 1 :].astype(q.dtype)], axis=0)
            new_q = jnp.concatenate([q[1:], fresh[None].astype(q.dtype)], axis=0)
            return stale, new_q
        return _push_pop(q, fresh)

    def _pop_push_param(q, fresh, s):
        """Param-queue pop with the extra data-axis depth E.

        Queue depth is base+E and q[r] holds the value pushed depth-r steps
        ago, so stage k's w_{t-(tau_k+E)} sits at row k — the SAME diagonal
        read, now defined for every stage (E >= 1 means even the last stage
        reads a queued snapshot instead of the fresh value)."""
        if E == 0:
            return _pop_push(q, fresh, s)
        if s == "stage":
            idx = jnp.arange(K)
            stale = q[idx, idx]
            new_q = jnp.concatenate([q[1:], fresh[None].astype(q.dtype)], axis=0)
            return stale, new_q
        return _push_pop(q, fresh)

    def init(params):
        flat, _ = jax.tree_util.tree_flatten(params)
        assert len(flat) == len(specs), "delay-spec list must match leaf count"
        state = {
            "inner": inner.init(params),
            "grad_q": [_q_shape(p, s) for p, s in zip(flat, specs)],
        }
        if store_params:
            state["param_q"] = [_p_queue(p, s) for p, s in zip(flat, specs)]
        return state

    def update(grads, state, params, step, aux=None):
        gflat, gdef = jax.tree_util.tree_flatten(grads)
        assert len(gflat) == len(specs), "delay-spec list must match leaf count"
        delayed, new_gq = [], []
        for g, q, s in zip(gflat, state["grad_q"], specs):
            if q is None:
                delayed.append(g)
                new_gq.append(None)
            else:
                old, nq = _pop_push(q, g, s)
                delayed.append(old)
                new_gq.append(nq)
        delayed_tree = jax.tree_util.tree_unflatten(gdef, delayed)

        inner_aux = dict(aux or {})
        new_state = {"grad_q": new_gq}
        if store_params:
            pflat, _ = jax.tree_util.tree_flatten(params)
            stale, new_pq = [], []
            for p, q, s in zip(pflat, state["param_q"], specs):
                if q is None:
                    stale.append(p)
                    new_pq.append(None)
                else:
                    old, nq = _pop_push_param(q, p, s)
                    stale.append(old)
                    new_pq.append(nq)
            inner_aux["stale_params"] = jax.tree_util.tree_unflatten(gdef, stale)
            new_state["param_q"] = new_pq

        try:
            updates, inner_state = inner.update(
                delayed_tree, state["inner"], params, step, aux=inner_aux or None
            )
        except TypeError:
            updates, inner_state = inner.update(delayed_tree, state["inner"], params, step)
        new_state["inner"] = inner_state
        return updates, new_state

    return Optimizer(init, update)


def max_delay(delays: Sequence[int]) -> int:
    return max([int(d) for d in delays] or [0])
