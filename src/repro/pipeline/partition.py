"""Layer -> pipeline-stage partition and per-parameter delay maps.

PipeDream semantics (paper Section 2.3 / Theorem E.6): with K stages indexed
k = 0..K-1, a parameter on stage k incurs gradient delay tau_k = K-1-k — the
earliest stage is the most stale. The embedding lives with stage 0, the final
norm / LM head with the last stage (matching the paper's setup where the
first/last stages also hold embedding and head).
"""
from __future__ import annotations

from typing import Any, List

import jax

from repro.configs.base import ModelConfig
from repro.core.layout import path_str
from repro.core.stage_aware import StageContext

# shared params living on the FIRST stage (delay tau = K-1); everything else
# shared (final norm, LM head) lives on the last stage (tau = 0)
FIRST_STAGE_SHARED = ("embed", "pos_emb", "frontend_proj")


def layer_to_stage(num_layers: int, num_stages: int) -> List[int]:
    """Contiguous equal split of layers over stages."""
    assert num_stages >= 1
    per = max(1, num_layers // num_stages)
    return [min(l // per, num_stages - 1) for l in range(num_layers)]


def stage_of_path(path: str, cfg: ModelConfig, num_stages: int) -> int:
    """Stage index for a parameter path. Requires scan_layers=False for
    per-layer resolution; stacked leaves get the stage of their first layer."""
    l2s = layer_to_stage(cfg.num_layers, num_stages)
    parts = path.split("/")
    if parts[0] == "blocks":
        idx = int(parts[1])
        if cfg.scan_layers:
            # stacked: leading axis spans superblocks; attribute to the stage
            # of the pattern position's first occurrence (dry-run only).
            return l2s[min(idx, cfg.num_layers - 1)]
        return l2s[idx]
    if parts[0] in ("embed", "pos_emb", "frontend_proj"):
        return 0
    # final_norm / lm_head
    return num_stages - 1


def leaf_stages(params: Any, cfg: ModelConfig, num_stages: int) -> List[int]:
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return [stage_of_path(path_str(p), cfg, num_stages) for p, _ in flat]


def leaf_delays(params: Any, cfg: ModelConfig, num_stages: int) -> List[int]:
    """Per-leaf gradient delay tau = K-1-stage, ordered like tree_flatten."""
    return [num_stages - 1 - s for s in leaf_stages(params, cfg, num_stages)]


def delay_tree(params: Any, cfg: ModelConfig, num_stages: int) -> Any:
    flat, treedef = jax.tree_util.tree_flatten(params)
    delays = leaf_delays(params, cfg, num_stages)
    return jax.tree_util.tree_unflatten(treedef, delays)


# ---------------------------------------------------------------------------
# StageContext constructors — the two parameter layouts
# ---------------------------------------------------------------------------


def stage_context_for_tree(
    params: Any, cfg: ModelConfig, num_stages: int, data_delay: int = 0
) -> StageContext:
    """Per-layer (sim) layout: every leaf lives wholly on one stage, so each
    delay is the scalar tau = K-1-stage of its owner. ``data_delay`` is the
    uniform extra staleness of an asynchronous data axis (total delay seen by
    delay-aware consumers = tau + data_delay)."""
    return StageContext(
        num_stages=num_stages,
        delays=tuple(leaf_delays(params, cfg, num_stages)),
        repeats=(1,) * len(jax.tree_util.tree_leaves(params)),
        data_delay=data_delay,
    )


def stage_context_for_stacked(
    stacked: Any, shared: Any, num_stages: int, data_delay: int = 0
) -> StageContext:
    """SPMD stage-stacked layout for the ``(stacked, shared)`` tuple.

    Stacked block leaves have shape ``(K, per, ...)``: per-stage delays
    ``(K-1, ..., 0)`` over the leading axis, each slot standing for ``per``
    canonical per-layer leaves. Shared leaves get the delay of the stage that
    owns them (embedding with stage 0, final norm / head with the last).
    """
    K = num_stages
    stage_delays = tuple(K - 1 - k for k in range(K))
    sflat = jax.tree_util.tree_leaves(stacked)
    pers = {int(x.shape[1]) for x in sflat if len(x.shape) > 1}
    assert len(pers) <= 1 and all(int(x.shape[0]) == K for x in sflat), (
        f"stacked leaves must share a (K={K}, per, ...) leading layout, got "
        f"{[tuple(x.shape) for x in sflat]}"
    )
    per = pers.pop() if pers else 1
    delays: List = [stage_delays] * len(sflat)
    repeats: List[int] = [per] * len(sflat)
    flat, _ = jax.tree_util.tree_flatten_with_path(shared)
    for path, _x in flat:
        root = path_str(path).split("/")[0]
        delays.append(K - 1 if root in FIRST_STAGE_SHARED else 0)
        repeats.append(1)
    return StageContext(
        num_stages=K, delays=tuple(delays), repeats=tuple(repeats),
        data_delay=data_delay,
    )
