"""Layer -> pipeline-stage partition and per-parameter delay maps.

PipeDream semantics (paper Section 2.3 / Theorem E.6): with K stages indexed
k = 0..K-1, a parameter on stage k incurs gradient delay tau_k = K-1-k — the
earliest stage is the most stale. The embedding lives with stage 0, the final
norm / LM head with the last stage (matching the paper's setup where the
first/last stages also hold embedding and head).
"""
from __future__ import annotations

from typing import Any, List

import jax

from repro.configs.base import ModelConfig
from repro.core.layout import path_str


def layer_to_stage(num_layers: int, num_stages: int) -> List[int]:
    """Contiguous equal split of layers over stages."""
    assert num_stages >= 1
    per = max(1, num_layers // num_stages)
    return [min(l // per, num_stages - 1) for l in range(num_layers)]


def stage_of_path(path: str, cfg: ModelConfig, num_stages: int) -> int:
    """Stage index for a parameter path. Requires scan_layers=False for
    per-layer resolution; stacked leaves get the stage of their first layer."""
    l2s = layer_to_stage(cfg.num_layers, num_stages)
    parts = path.split("/")
    if parts[0] == "blocks":
        idx = int(parts[1])
        if cfg.scan_layers:
            # stacked: leading axis spans superblocks; attribute to the stage
            # of the pattern position's first occurrence (dry-run only).
            return l2s[min(idx, cfg.num_layers - 1)]
        return l2s[idx]
    if parts[0] in ("embed", "pos_emb", "frontend_proj"):
        return 0
    # final_norm / lm_head
    return num_stages - 1


def leaf_stages(params: Any, cfg: ModelConfig, num_stages: int) -> List[int]:
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return [stage_of_path(path_str(p), cfg, num_stages) for p, _ in flat]


def leaf_delays(params: Any, cfg: ModelConfig, num_stages: int) -> List[int]:
    """Per-leaf gradient delay tau = K-1-stage, ordered like tree_flatten."""
    return [num_stages - 1 - s for s in leaf_stages(params, cfg, num_stages)]


def delay_tree(params: Any, cfg: ModelConfig, num_stages: int) -> Any:
    flat, treedef = jax.tree_util.tree_flatten(params)
    delays = leaf_delays(params, cfg, num_stages)
    return jax.tree_util.tree_unflatten(treedef, delays)
