"""Compatibility shim: the shard_map pipeline runtime moved under the engine
subsystem (`repro.engine.spmd` + `repro.engine.schedules`, DESIGN.md §3) when
the train loop was unified behind `PipelineEngine`. Import sites keep working
through this module."""
from repro.engine.schedules import (  # noqa: F401
    SCHEDULES,
    make_1f1b_grad,
    make_schedule_grad,
    schedule_activation_bytes,
)
from repro.engine.spmd import (  # noqa: F401
    SpmdEngine,
    make_pipeline_grad,
    make_pipeline_loss,
    spmd_delay_specs,
    stack_stage_params,
    unstack_stage_params,
)

__all__ = [
    "SCHEDULES",
    "SpmdEngine",
    "make_1f1b_grad",
    "make_pipeline_grad",
    "make_pipeline_loss",
    "make_schedule_grad",
    "schedule_activation_bytes",
    "spmd_delay_specs",
    "stack_stage_params",
    "unstack_stage_params",
]
