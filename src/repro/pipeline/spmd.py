"""Compatibility shim: the shard_map pipeline runtime moved under the engine
subsystem (`repro.engine.spmd`, DESIGN.md §3) when the train loop was unified
behind `PipelineEngine`. Import sites keep working through this module."""
from repro.engine.spmd import (  # noqa: F401
    SpmdEngine,
    make_pipeline_grad,
    make_pipeline_loss,
    spmd_delay_specs,
    stack_stage_params,
    unstack_stage_params,
)

__all__ = [
    "SpmdEngine",
    "make_pipeline_grad",
    "make_pipeline_loss",
    "spmd_delay_specs",
    "stack_stage_params",
    "unstack_stage_params",
]
