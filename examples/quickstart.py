"""Quickstart: train a tiny decoder with Adam-with-Basis-Rotation under a
simulated 8-stage asynchronous pipeline, and compare against vanilla async
Adam (PipeDream) — the paper's core experiment in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    ModelConfig,
    OptimizerConfig,
)
from repro.data import batches
from repro.models import init_model, param_count
from repro.optim.factory import build_optimizer
from repro.pipeline.simulate import run_sim_training

CFG = ModelConfig(
    name="quickstart_lm",
    num_layers=8, d_model=64, d_ff=256, vocab_size=128, max_seq_len=64,
    attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    pattern=(BlockSpec("attn", "dense"),),
    norm="layernorm", mlp_act="gelu", learnable_pos_emb=True,
    scan_layers=False,  # per-layer params => exact per-stage delays
)
STAGES, STEPS = 8, 200


def main():
    params = init_model(jax.random.PRNGKey(0), CFG)
    print(f"model: {param_count(params):,} params, {STAGES} pipeline stages "
          f"(max gradient delay = {STAGES - 1})\n")
    results = {}
    for name in ("adam", "basis_rotation"):
        ocfg = OptimizerConfig(name=name, learning_rate=3e-3, total_steps=STEPS,
                               rotation_freq=10)
        opt = build_optimizer(ocfg, params, CFG, num_stages=STAGES)
        label = "PipeDream (async Adam)" if name == "adam" else "Basis rotation"
        print(f"--- {label} ---")
        _, _, losses = run_sim_training(
            CFG, opt, batches(CFG, 8, 32, seed=0), steps=STEPS,
            params=params, log_every=40,
        )
        results[label] = losses

    print("\nfinal losses (mean of last 10 steps):")
    for label, losses in results.items():
        print(f"  {label:26s} {sum(losses[-10:]) / 10:.4f}")


if __name__ == "__main__":
    main()
