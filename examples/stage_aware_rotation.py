"""Stage-aware basis rotation (paper Section 4.3 / Appendix I): allocate the
basis-refresh budget proportionally to per-stage delay and compare uniform /
stage-aware / reversed allocations at the same total budget.

    PYTHONPATH=src python examples/stage_aware_rotation.py

The same allocations run on the real SPMD runtime (per-stage periods
vectorized inside the stacked leaves, DESIGN.md §5a):

    PYTHONPATH=src python -m repro.launch.train --smoke --backend spmd \
        --optimizer basis_rotation --stage-aware [--use-kernels]
    python -m benchmarks.fig17_stage_aware --backend spmd
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    ModelConfig,
    OptimizerConfig,
)
from repro.core.stage_aware import NEVER, freqs_for_delays
from repro.data import batches
from repro.models import init_model
from repro.optim.factory import build_optimizer
from repro.pipeline.partition import leaf_delays
from repro.pipeline.simulate import run_sim_training

CFG = ModelConfig(
    num_layers=8, d_model=64, d_ff=256, vocab_size=128, max_seq_len=64,
    attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    pattern=(BlockSpec("attn", "dense"),), norm="layernorm", mlp_act="gelu",
    learnable_pos_emb=True, scan_layers=False,
)
STAGES, STEPS = 8, 200


def main():
    params = init_model(jax.random.PRNGKey(0), CFG)
    delays = leaf_delays(params, CFG, STAGES)
    freqs = freqs_for_delays(delays, STAGES, 10)
    per_stage = sorted({(d, f) for d, f in zip(delays, freqs)})
    print("delay -> refresh period (NEVER = no refresh):")
    for d, f in per_stage:
        print(f"  tau={d}: every {'NEVER' if f >= NEVER else f} steps")

    for label, kw in [
        ("uniform", {}),
        ("stage-aware", {"stage_aware": True}),
        ("reversed (ablation)", {"stage_aware": True, "stage_aware_reversed": True}),
    ]:
        ocfg = OptimizerConfig(name="basis_rotation", learning_rate=3e-3,
                               total_steps=STEPS, rotation_freq=10, **kw)
        opt = build_optimizer(ocfg, params, CFG, num_stages=STAGES)
        _, _, losses = run_sim_training(
            CFG, opt, batches(CFG, 8, 32, seed=0), steps=STEPS, params=params
        )
        print(f"{label:22s} final={sum(losses[-10:]) / 10:.4f}")


if __name__ == "__main__":
    main()
