"""Serving example: batched autoregressive decoding with a KV cache through
the same forward_decode path the decode_32k / long_500k dry-runs lower.

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral_8x22b
(uses the SMOKE config so it runs on CPU; the full config is exercised via
the AOT dry-run.)
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import forward_decode, init_cache, init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    max_len = args.prompt_len + args.gen_len
    cache = init_cache(cfg, args.batch, max_len)

    tok_shape = (args.batch, 1, cfg.num_codebooks) if cfg.num_codebooks > 1 else (args.batch, 1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len) +
                                ((cfg.num_codebooks,) if cfg.num_codebooks > 1 else ()),
                                0, cfg.vocab_size)

    @jax.jit
    def step(params, tok, cache, pos):
        return forward_decode(params, cfg, tok, cache, pos)

    # prefill by teacher-forcing the prompt through the decode path
    for t in range(args.prompt_len):
        logits, cache = step(params, prompt[:, t][:, None], cache, jnp.int32(t))

    tok = jnp.argmax(logits[:, -1], axis=-1).reshape(tok_shape)
    out = [tok]
    t0 = time.perf_counter()
    for t in range(args.prompt_len, max_len - 1):
        logits, cache = step(params, tok, cache, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1).reshape(tok_shape)
        out.append(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} generated={gen.shape}")
    print(f"throughput: {args.batch * (len(out) - 1) / dt:.1f} tokens/s (CPU, smoke cfg)")
    print("first sequence:", [int(x) for x in jnp.ravel(gen[0])[:16]])


if __name__ == "__main__":
    main()
