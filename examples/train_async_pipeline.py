"""End-to-end driver (deliverable b): train a ~100M-parameter model for a few
hundred steps under simulated asynchronous pipeline parallelism with basis
rotation, checkpointing included. This wraps the production launcher.

    PYTHONPATH=src python examples/train_async_pipeline.py [--steps 300]

(paper_95m is the paper's own nanoGPT configuration: 32 blocks, d_model=384,
~96M params; pass --quick for a CI-sized run.)
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true",
                    help="reduced model (CI-sized), 60 steps")
    ap.add_argument("--backend", default="sim", choices=["sim", "spmd"],
                    help="sim: exact-delay simulation; spmd: shard_map runtime")
    ap.add_argument("--schedule", default="fill_drain",
                    choices=["fill_drain", "1f1b"],
                    help="spmd tick schedule (1f1b: O(stages) activation stash)")
    args = ap.parse_args()
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "paper_95m",
        "--backend", args.backend,
        "--stages", "2" if args.quick else "8",  # smoke cfg has 2 layers
        "--optimizer", "basis_rotation",
        "--rotation-source", "2nd", "--rotation-geometry", "bilateral",
        "--steps", str(60 if args.quick else args.steps),
        "--batch", "4", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_ckpt_95m",
        "--out", "experiments/train_95m_async.json",
    ]
    # always forwarded: an explicit --schedule with the sim backend surfaces
    # train.py's validation error instead of being silently ignored here
    cmd.extend(["--schedule", args.schedule])
    if args.quick:
        cmd.append("--smoke")
    print(" ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={"PYTHONPATH": "src", **__import__("os").environ}))


if __name__ == "__main__":
    main()
