import os

# Smoke tests and benches must see exactly ONE device (the dry-run, and only
# the dry-run, forces 512 — in its own process). Keep jax defaults here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
