"""Model substrate: train/decode consistency for every mixer family,
chunked-vs-dense path equivalence, frontends, loss sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    MoEConfig,
    ModelConfig,
    SSMConfig,
)
from repro.models import (
    forward_decode,
    forward_train,
    init_cache,
    init_model,
    loss_fn,
)

B, S, V = 2, 16, 97


def _decode_all(params, cfg, toks, steps=S):
    cache = init_cache(cfg, B, steps)
    outs = []
    for t in range(steps):
        lg, cache = forward_decode(params, cfg, toks[:, t : t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    return jnp.stack(outs, 1)


def _consistency(cfg, rtol=2e-4, atol=2e-4):
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = forward_train(params, cfg, toks)
    dec = _decode_all(params, cfg, toks)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=rtol, atol=atol)


def test_gqa_consistency():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=V,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16,
                                  qk_norm=True, qkv_bias=True),
        pattern=(BlockSpec("attn", "dense"),),
    )
    _consistency(cfg)


def test_swa_ring_buffer_consistency():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=V,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16, window=6),
        pattern=(BlockSpec("attn", "dense"),),
    )
    _consistency(cfg)


def test_mla_consistency():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=V,
        attention=AttentionConfig(kind="mla", num_heads=4, kv_lora_rank=32,
                                  q_lora_rank=48, qk_nope_head_dim=16,
                                  qk_rope_head_dim=8, v_head_dim=16),
        pattern=(BlockSpec("attn", "dense"),),
    )
    _consistency(cfg)


def test_mamba_consistency():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=V,
        ssm=SSMConfig(kind="mamba", d_state=8, d_conv=4, expand=2),
        pattern=(BlockSpec("mamba", "dense"),),
    )
    _consistency(cfg, rtol=1e-3, atol=1e-3)


def test_xlstm_consistency():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=0, vocab_size=V,
        ssm=SSMConfig(kind="mlstm", num_heads=4, proj_factor=2.0),
        pattern=(BlockSpec("mlstm", "none"), BlockSpec("slstm", "none")),
    )
    _consistency(cfg, rtol=1e-3, atol=1e-3)


def test_moe_train_runs_and_aux_positive():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=V,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, d_ff_expert=64),
        pattern=(BlockSpec("attn", "moe"),),
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
    loss, metrics = loss_fn(params, cfg, {"tokens": toks, "labels": toks})
    assert jnp.isfinite(loss)
    assert metrics["aux"] > 0


def test_moe_decode_matches_train_at_high_capacity():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=V,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=8.0),
        pattern=(BlockSpec("attn", "moe"),),
    )
    _consistency(cfg, rtol=1e-3, atol=1e-3)


def test_chunked_attention_equals_dense(monkeypatch):
    from repro.models import attention as attn_mod

    monkeypatch.setattr(attn_mod, "CHUNKED_ATTN_THRESHOLD", 8)
    monkeypatch.setattr(attn_mod, "QUERY_BLOCK", 8)
    cfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16)
    key = jax.random.PRNGKey(0)
    params = attn_mod.init_attention(key, 64, cfg, jnp.float32)
    x = jax.random.normal(key, (B, 32, 64))
    chunked = attn_mod.gqa_train(params, x, cfg)
    monkeypatch.setattr(attn_mod, "CHUNKED_ATTN_THRESHOLD", 10_000)
    dense = attn_mod.gqa_train(params, x, cfg)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=2e-4, atol=2e-4)


def test_chunked_mamba_equals_dense(monkeypatch):
    from repro.models import mamba as mb

    key = jax.random.PRNGKey(0)
    scfg = SSMConfig(kind="mamba", d_state=8, d_conv=4, expand=2)
    params = mb.init_mamba(key, 32, scfg, jnp.float32)
    u = jax.random.normal(key, (B, 32, 32))
    monkeypatch.setattr(mb, "SSM_CHUNK", 8)
    chunked = mb.mamba_train(params, u, scfg)
    monkeypatch.setattr(mb, "SSM_CHUNK", 1 << 20)
    dense = mb.mamba_train(params, u, scfg)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=1e-4, atol=1e-4)


def test_chunked_mlstm_equals_dense(monkeypatch):
    from repro.models import xlstm as xl

    key = jax.random.PRNGKey(0)
    scfg = SSMConfig(kind="mlstm", num_heads=4, proj_factor=2.0)
    params = xl.init_mlstm(key, 32, scfg, jnp.float32)
    u = jax.random.normal(key, (B, 32, 32))
    monkeypatch.setattr(xl, "MLSTM_CHUNK_THRESHOLD", 8)
    monkeypatch.setattr(xl, "MLSTM_QUERY_BLOCK", 8)
    chunked = xl.mlstm_train(params, u, scfg)
    monkeypatch.setattr(xl, "MLSTM_CHUNK_THRESHOLD", 10_000)
    dense = xl.mlstm_train(params, u, scfg)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=1e-4, atol=1e-4)


def test_vlm_frontend_and_ignore_labels():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=V, family="vlm",
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),),
        frontend="vision", frontend_tokens=4, frontend_dim=32,
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
    fr = jax.random.normal(jax.random.PRNGKey(2), (B, 4, 32))
    labels = toks.at[:, :4].set(-100)  # ignored positions
    loss, _ = loss_fn(params, cfg, {"tokens": toks, "labels": labels, "frontend": fr})
    assert jnp.isfinite(loss)
    logits, _ = forward_train(params, cfg, toks, fr)
    assert logits.shape == (B, S, V)  # image positions trimmed


def test_audio_multi_codebook():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=50, num_codebooks=4,
        family="audio",
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),),
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S, 4), 0, 50)
    logits, _ = forward_train(params, cfg, toks)
    assert logits.shape == (B, S, 4, 50)
    loss, _ = loss_fn(params, cfg, {"tokens": toks, "labels": toks})
    assert jnp.isfinite(loss)


def test_scan_vs_unstacked_equivalence():
    """scan_layers=True/False compute the same function (different param
    layout, same init keys => cannot compare params; compare via structure)."""
    cfg = ModelConfig(
        num_layers=4, d_model=32, d_ff=64, vocab_size=V,
        attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),),
    )
    key = jax.random.PRNGKey(0)
    stacked = init_model(key, cfg)
    flat = init_model(key, cfg.replace(scan_layers=False))
    # move the unstacked params into the stacked layout and compare outputs
    restacked = jax.tree.map(lambda *xs: jnp.stack(xs), *flat["blocks"])
    donor = dict(flat)
    donor["blocks"] = (restacked,)
    toks = jax.random.randint(key, (B, S), 0, V)
    out1, _ = forward_train(donor, cfg, toks)
    out2, _ = forward_train(flat, cfg.replace(scan_layers=False), toks)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=2e-5, atol=2e-5)


def test_chunked_ce_matches_dense():
    """loss_chunk never changes the loss or gradients (beyond-paper opt)."""
    from repro.models.model import loss_fn

    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=97,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),),
    )
    p = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 97)
    labels = toks.at[:, :5].set(-100)
    batch = {"tokens": toks, "labels": labels}
    l1, _ = loss_fn(p, cfg, batch)
    for chunk in (16, 24):  # 24 exercises the tail-chunk path
        l2, _ = loss_fn(p, cfg.replace(loss_chunk=chunk), batch)
        assert abs(float(l1) - float(l2)) < 1e-5
    g1 = jax.grad(lambda pp: loss_fn(pp, cfg, batch)[0])(p)
    g2 = jax.grad(lambda pp: loss_fn(pp, cfg.replace(loss_chunk=16), batch)[0])(p)
    d = max(jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)))
    assert d < 1e-5


def test_remat_policies_agree():
    from repro.models.model import loss_fn

    cfg = ModelConfig(
        num_layers=4, d_model=64, d_ff=128, vocab_size=97,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),),
    )
    p = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 97)
    batch = {"tokens": toks, "labels": toks}
    g1 = jax.grad(lambda pp: loss_fn(pp, cfg, batch)[0])(p)
    g2 = jax.grad(lambda pp: loss_fn(pp, cfg.replace(remat_policy="dots"), batch)[0])(p)
    d = max(jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)))
    assert d < 1e-5


def test_moe_dispatch_conservation():
    """Routing invariant: with ample capacity, every token's MoE output equals
    the gate-weighted sum of its experts' MLP outputs (hypothesis-style sweep
    over seeds)."""
    from repro.models.moe import _top_k_gates, apply_moe, init_moe
    from repro.configs.base import MoEConfig

    D, E = 16, 4
    mcfg = MoEConfig(num_experts=E, top_k=2, d_ff_expert=32, capacity_factor=8.0)
    params = init_moe(jax.random.PRNGKey(0), D, 32, mcfg, "swiglu", jnp.float32)
    for seed in range(5):
        x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, D))
        y, _ = apply_moe(params, x, mcfg)
        # manual dense computation
        xt = x.reshape(-1, D)
        logits = xt @ params["router"]
        gates, _ = _top_k_gates(logits, 2)

        def expert(e, t):
            h = jax.nn.silu(t @ params["w_gate_e"][e]) * (t @ params["w_up_e"][e])
            return h @ params["w_down_e"][e]

        want = jnp.stack([
            sum(gates[i, e] * expert(e, xt[i]) for e in range(E))
            for i in range(xt.shape[0])
        ]).reshape(x.shape)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens_but_stays_finite():
    """At tiny capacity most tokens drop: output shrinks but remains finite
    and the aux loss still registers load imbalance."""
    from repro.models.moe import apply_moe, init_moe
    from repro.configs.base import MoEConfig

    D = 16
    mcfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32, capacity_factor=0.1)
    params = init_moe(jax.random.PRNGKey(0), D, 32, mcfg, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, D))
    y, aux = apply_moe(params, x, mcfg)
    assert jnp.all(jnp.isfinite(y)) and jnp.isfinite(aux)
    y_full, _ = apply_moe(
        params, x, MoEConfig(num_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0)
    )
    # dropped tokens => strictly less routed mass
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_full))
