"""Unit tests for the HLO collective parser + replica-group auditor
(`repro.analysis.hlo`) on fixture snippets of optimized-HLO text, and for
`Topology.replica_groups` — the declared ground truth the auditor compares
against. The parser is shared with `launch/roofline.py`; the re-export must
stay alive because `launch/dryrun.py` imports through it."""
import pytest

from repro.analysis.hlo import (
    CollectiveInstr,
    check_collective_axes,
    check_data_reduction,
    collective_stats,
    declared_groupings,
    parse_collectives,
    shape_bytes,
)
from repro.launch.topology import Topology

# fixture mimicking jax 0.4.37 / CPU optimized-module output: explicit and
# iota replica groups, async -start/-done pair, tuple-combined all-reduce,
# and a collective-permute with source_target_pairs
FIXTURE_HLO = """
HloModule jit_step, entry_computation_layout={(f32[4,8]{1,0})->f32[4,8]{1,0}}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %sum = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p: f32[4,8]) -> f32[4,8] {
  %p = f32[4,8]{1,0} parameter(0)
  %ar = f32[4,8]{1,0} all-reduce(f32[4,8]{1,0} %p), channel_id=1, replica_groups={{0,1},{2,3}}, use_global_device_ids=true, to_apply=%add
  %ag = f32[8,8]{1,0} all-gather(f32[4,8]{1,0} %p), channel_id=2, replica_groups=[2,2]<=[4], dimensions={0}, use_global_device_ids=true
  %rs = f32[2,8]{1,0} reduce-scatter(f32[4,8]{1,0} %p), channel_id=3, replica_groups={{0,2},{1,3}}, dimensions={0}, to_apply=%add
  %cp = f32[4,8]{1,0} collective-permute(f32[4,8]{1,0} %p), channel_id=4, source_target_pairs={{0,2},{1,3}}
  %ars = f32[4,8]{1,0} all-reduce-start(f32[4,8]{1,0} %p), channel_id=5, replica_groups={{0,1,2,3}}, to_apply=%add
  %ard = f32[4,8]{1,0} all-reduce-done(f32[4,8]{1,0} %ars)
  %tup = (f32[4,8]{1,0}, bf16[2]{0}) all-reduce(f32[4,8]{1,0} %p, bf16[2]{0} %q), channel_id=6, replica_groups={}, to_apply=%add
  ROOT %out = f32[4,8]{1,0} add(f32[4,8]{1,0} %ar, f32[4,8]{1,0} %ard)
}
"""


def test_parse_collectives_ops_and_bytes():
    instrs = parse_collectives(FIXTURE_HLO)
    assert [i.op for i in instrs] == [
        "all-reduce", "all-gather", "reduce-scatter", "collective-permute",
        "all-reduce", "all-reduce",
    ]
    by = {}
    for i in instrs:
        by.setdefault(i.op, []).append(i)
    assert by["all-reduce"][0].out_bytes == 4 * 8 * 4
    assert by["all-gather"][0].out_bytes == 8 * 8 * 4  # gathered (larger) side
    assert by["reduce-scatter"][0].out_bytes == 2 * 8 * 4  # scattered side
    # tuple-combined all-reduce bills both output elements (f32 + bf16)
    assert by["all-reduce"][2].out_bytes == 4 * 8 * 4 + 2 * 2
    # the -done half is not double counted
    assert len(by["all-reduce"]) == 3


def test_parse_collectives_replica_groups_both_forms():
    instrs = parse_collectives(FIXTURE_HLO)
    ar, ag, rs, cp, ars, tup = instrs
    assert ar.replica_groups == ((0, 1), (2, 3))
    # iota form [2,2]<=[4] expands row-major
    assert ag.replica_groups == ((0, 1), (2, 3))
    assert rs.replica_groups == ((0, 2), (1, 3))
    assert cp.source_target_pairs == ((0, 2), (1, 3))
    assert ars.replica_groups == ((0, 1, 2, 3),)
    assert tup.replica_groups == ()  # {} = all devices together


def test_parse_iota_with_transpose():
    hlo = ("%ag = f32[4,4]{1,0} all-gather(f32[2,4]{1,0} %p), "
           "replica_groups=[2,2]<=[2,2]T(1,0), dimensions={0}")
    (ins,) = parse_collectives("%x = f32[] add(...)\n" + hlo)
    assert ins.replica_groups == ((0, 2), (1, 3))


def test_collective_stats_totals_and_roofline_reexport():
    stats = collective_stats(FIXTURE_HLO)
    assert stats.count_by_op["all-reduce"] == 3
    assert stats.count_by_op["collective-permute"] == 1
    assert stats.total_bytes == sum(
        i.out_bytes for i in parse_collectives(FIXTURE_HLO)
    )
    # launch/roofline.py (and through it launch/dryrun.py) must keep working
    from repro.launch import roofline

    assert roofline.collective_stats is collective_stats
    assert shape_bytes("bf16", "2,3") == 12


# ---------------------------------------------------------------------------
# Topology.replica_groups: the declared ground truth
# ---------------------------------------------------------------------------


def test_replica_groups_single_pod():
    t = Topology(stages=2, data=2)  # shape (2, 2), row-major ids 0..3
    assert t.replica_groups(("stage",)) == ((0, 2), (1, 3))
    assert t.replica_groups(("data",)) == ((0, 1), (2, 3))
    assert t.replica_groups(("stage", "data")) == ((0, 1, 2, 3),)
    with pytest.raises(ValueError):
        t.replica_groups(("pod",))  # pod axis not declared when pods == 1
    with pytest.raises(ValueError):
        t.replica_groups(())


def test_replica_groups_multi_pod():
    t = Topology(stages=2, data=1, pods=2)  # shape (2, 2, 1)
    assert t.replica_groups(("stage",)) == ((0, 1), (2, 3))
    assert t.replica_groups(("pod",)) == ((0, 2), (1, 3))
    # the combined data-axes (pod, data) group: one per stage
    assert t.replica_groups(t.data_axes) == ((0, 2), (1, 3))
    groupings = declared_groupings(t)
    assert frozenset({frozenset({0, 2}), frozenset({1, 3})}) in \
        groupings.values()
    assert len(groupings) == 7  # all non-empty subsets of 3 axes


# ---------------------------------------------------------------------------
# the auditor checks on synthetic instruction lists
# ---------------------------------------------------------------------------


def _ar(groups):
    return CollectiveInstr(op="all-reduce", out_bytes=128,
                           replica_groups=groups, line="fixture")


def _cp(pairs):
    return CollectiveInstr(op="collective-permute", out_bytes=128,
                           source_target_pairs=pairs, line="fixture")


def test_check_collective_axes_accepts_declared_groupings():
    t = Topology(stages=2, data=2)
    instrs = [
        _ar(((0, 2), (1, 3))),   # stage reduction
        _ar(((0, 1), (2, 3))),   # data reduction
        _ar(((0, 1, 2, 3),)),    # global (e.g. grad-clip norm)
        _ar(()),                 # replica_groups={} = global
        _ar(((0,), (1,), (2,), (3,))),  # degenerate singletons: accepted
        _cp(((0, 2), (1, 3))),   # neighbour shift along stage
    ]
    res = check_collective_axes(instrs, t)
    assert res.passed, res.detail
    assert "stage" in str(res.data["matched"]["all-reduce"])


def test_check_collective_axes_rejects_stray_groups_and_cross_axis_permute():
    t = Topology(stages=2, data=2)
    diag = check_collective_axes([_ar(((0, 3), (1, 2)))], t)
    assert not diag.passed and "undeclared" in diag.detail

    # permute along the data axis: activations leaking between replicas
    leak = check_collective_axes([_cp(((0, 1),))], t)
    assert not leak.passed and "stage" in leak.detail

    # multi-pod: a permute crossing the pod axis is also rejected
    t2 = Topology(stages=2, data=1, pods=2)
    cross_pod = check_collective_axes([_cp(((0, 2),))], t2)
    assert not cross_pod.passed
    ok = check_collective_axes([_cp(((0, 1), (2, 3)))], t2)
    assert ok.passed, ok.detail


def test_check_data_reduction_iff():
    sharded = Topology(stages=2, data=2)
    want = sharded.replica_groups(("data",))
    assert check_data_reduction([_ar(want)], sharded).passed
    missing = check_data_reduction([_ar(((0, 2), (1, 3)))], sharded)
    assert not missing.passed and "missing" in missing.detail

    # 1 data shard: the degenerate singleton-group pmean XLA may leave in
    # place does NOT count as a data reduction — absence is required and ok
    solo = Topology(stages=2, data=1)
    assert check_data_reduction([], solo).passed
    leftover = [_ar(((0,), (1,)))]
    assert check_data_reduction(leftover, solo).passed

    # multi-pod with data=1 still data-reduces across pods
    pods = Topology(stages=2, data=1, pods=2)
    assert pods.data_shards == 2
    assert check_data_reduction([_ar(pods.replica_groups(pods.data_axes))],
                                pods).passed
    assert not check_data_reduction([], pods).passed
