"""End-to-end system behaviour: simulated async-pipeline training converges,
basis rotation beats the vanilla async baseline under large delay, and the
shard_map pipeline runtime matches the single-device reference (subprocess —
it needs a multi-device fake topology that must not leak into this process).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    ModelConfig,
    OptimizerConfig,
)
from repro.data import batches
from repro.models import init_model
from repro.optim.factory import build_optimizer
from repro.pipeline.simulate import run_sim_training

CFG = ModelConfig(
    num_layers=8, d_model=64, d_ff=256, vocab_size=128, max_seq_len=64,
    attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
    learnable_pos_emb=True, norm="layernorm", mlp_act="gelu",
)
STEPS = 120


def _run(name, stages, steps=STEPS, **okw):
    ocfg = OptimizerConfig(name=name, learning_rate=3e-3, total_steps=steps,
                           rotation_freq=5, **okw)
    params = init_model(jax.random.PRNGKey(0), CFG)
    opt = build_optimizer(ocfg, params, CFG, num_stages=stages)
    _, _, losses = run_sim_training(
        CFG, opt, batches(CFG, 8, 32, seed=0), steps=steps, params=params
    )
    return losses


def _avg_tail(losses, k=10):
    return sum(losses[-k:]) / k


def test_training_converges_no_delay():
    losses = _run("adam", stages=1)
    assert _avg_tail(losses) < losses[0] - 1.0


def test_delay_hurts_vanilla_adam():
    """Reproduces the paper's core observation (Fig. 2a): more stages =>
    slower convergence for PipeDream-style async Adam."""
    l1 = _run("adam", stages=1)
    l8 = _run("adam", stages=8)
    assert _avg_tail(l8) > _avg_tail(l1) - 1e-3


def test_basis_rotation_beats_vanilla_under_delay():
    """The paper's core claim (Fig. 5): under large delay, basis rotation
    converges faster than vanilla async Adam."""
    base = _run("adam", stages=8)
    rot = _run("basis_rotation", stages=8)
    assert _avg_tail(rot) < _avg_tail(base) + 0.05
    # and is no worse than 25% behind the zero-delay reference
    ref = _run("adam", stages=1)
    assert _avg_tail(rot) < _avg_tail(ref) * 1.25 + 0.5


def test_all_methods_stable_under_delay():
    for name in ["pipedream_lr", "nesterov", "delay_compensation"]:
        losses = _run(name, stages=4, steps=60)
        assert all(jnp.isfinite(jnp.asarray(losses))), name


SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, json
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec
from repro.launch.mesh import make_mesh_compat, use_mesh
from repro.models import init_model
from repro.models.model import loss_fn
from repro.pipeline.spmd import stack_stage_params, make_pipeline_grad, make_pipeline_loss

cfg = ModelConfig(num_layers=4, d_model=32, d_ff=64, vocab_size=64, max_seq_len=64,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
                  pattern=(BlockSpec("attn","dense"),), scan_layers=False)
params = init_model(jax.random.PRNGKey(0), cfg)
K, M = 4, 4
stacked, shared = stack_stage_params(params, cfg, K)
mesh = make_mesh_compat((K, 2), ("stage", "data"))
toks = jax.random.randint(jax.random.PRNGKey(1), (M, 4, 16), 0, 64)
labels = jax.random.randint(jax.random.PRNGKey(2), (M, 4, 16), 0, 64)
batch = {"tokens": toks, "labels": labels}
grad_fn = make_pipeline_grad(cfg, mesh, K, M)
with use_mesh(mesh):
    loss, (gs, gsh) = jax.jit(grad_fn)(stacked, shared, batch)
flat = {"tokens": toks.reshape(-1, 16), "labels": labels.reshape(-1, 16)}
(ref_loss, _), ref_g = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, flat)
re_stacked, _ = stack_stage_params({**{k: v for k, v in ref_g.items()}}, cfg, K)
d_blocks = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), gs, re_stacked)))
d_loss = abs(float(loss) - float(ref_loss))

def n_eqns(jaxpr):
    total = len(jaxpr.eqns)
    for eq in jaxpr.eqns:
        for v in eq.params.values():
            if hasattr(v, "jaxpr"):
                total += n_eqns(v.jaxpr)
            elif hasattr(v, "eqns"):
                total += n_eqns(v)
    return total

# scanned schedule: trace size must not grow with microbatch count
sizes = []
for m in (4, 16):
    lf = make_pipeline_loss(cfg, mesh, K, m)
    b = {"tokens": jnp.zeros((m, 4, 16), jnp.int32),
         "labels": jnp.zeros((m, 4, 16), jnp.int32)}
    sizes.append(n_eqns(jax.make_jaxpr(lf)(stacked, shared, b).jaxpr))
print(json.dumps({"d_loss": d_loss, "d_blocks": d_blocks,
                  "eqns_m4": sizes[0], "eqns_m16": sizes[1]}))
"""


def test_spmd_pipeline_matches_reference():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["d_loss"] < 1e-4
    assert res["d_blocks"] < 1e-4
    # jaxpr size constant in num_microbatches (lax.scan schedule, no unroll)
    assert res["eqns_m16"] == res["eqns_m4"]


def test_dryrun_smoke_subprocess():
    """One real (arch x shape) dry-run end-to-end through the CLI."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen1_5_0_5b", "--shape", "decode_32k"],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    row = json.loads(out.stdout.strip().splitlines()[-1])
    assert row["status"] == "ok"
    assert row["flops"] > 0 and row["bottleneck"] in ("compute", "memory", "collective")


PIPE_TRAIN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, json
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec, OptimizerConfig
from repro.data import batches
from repro.engine import SpmdEngine, LoopConfig, run_loop
from repro.launch.mesh import make_mesh_compat

cfg = ModelConfig(num_layers=4, d_model=32, d_ff=64, vocab_size=64, max_seq_len=64,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
                  pattern=(BlockSpec("attn","dense"),), scan_layers=False)
K, M = 4, 4
mesh = make_mesh_compat((K, 2), ("stage", "data"))
ocfg = OptimizerConfig(name="basis_rotation", learning_rate=3e-3, total_steps=25,
                       rotation_freq=5, schedule="constant")
engine = SpmdEngine(cfg, ocfg, num_stages=K, num_microbatches=M, mesh=mesh)
state = engine.init_state(key=jax.random.PRNGKey(0))
state, losses = run_loop(engine, batches(cfg, M * 4, 16, seed=0),
                         LoopConfig(steps=25), state=state)
print(json.dumps({"first": losses[0], "last": sum(losses[-5:]) / 5}))
"""


def test_spmd_pipeline_async_training_converges():
    """End-to-end: the SpmdEngine — shard_map pipeline grads + per-stage
    delayed basis-rotation updates under the shared loop — reduces the loss."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", PIPE_TRAIN_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["last"] < res["first"] - 0.3, res
