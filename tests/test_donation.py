"""Donated train step: the `input_output_alias` parser on fixture HLO, the
`check_donation` analyzer check against the REAL compiled SpmdEngine step,
and (subprocess — needs the 2-stage analyzer topology) a seeded mutation
that strips `donate_argnums` and must flip exactly the donation check."""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis.hlo import check_donation, parse_input_output_aliases

FIXTURE = """
HloModule jit__step, is_scheduled=true, entry_computation_layout={...}, \
input_output_alias={ {0}: (0, {}, may-alias), {1}: (2, {}, must-alias), \
{3,0}: (5, {}, may-alias) }, allow_spmd_sharding_propagation_to_output={true}

ENTRY %main () -> () {
}
"""


def test_parse_input_output_aliases_fixture():
    assert parse_input_output_aliases(FIXTURE) == {0: 0, 2: 1, 5: 3}
    assert parse_input_output_aliases("HloModule bare\n") == {}


def test_check_donation_fixture():
    ok = check_donation(FIXTURE, [0, 2, 5])
    assert ok.passed, ok.detail
    missing = check_donation(FIXTURE, [0, 1, 2])
    assert not missing.passed
    assert missing.data["missing"] == [1]
    assert "donate_argnums" in missing.detail
    # queue leaves are reported, never required
    queues = check_donation(FIXTURE, [0, 2], queue_params=[3, 5])
    assert queues.passed
    assert queues.data["queue_leaves"] == 2
    assert queues.data["queue_aliased"] == 1


@pytest.fixture(scope="module")
def engines():
    from repro.configs.base import (
        AttentionConfig, BlockSpec, ModelConfig, OptimizerConfig,
    )
    from repro.engine.spmd import SpmdEngine
    from repro.launch.topology import Topology

    cfg = ModelConfig(
        num_layers=2, d_model=16, d_ff=24, vocab_size=96, max_seq_len=32,
        attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=8),
        pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
    )
    ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=4,
                           schedule="constant")

    def make(donate):
        return SpmdEngine(
            cfg, ocfg, num_stages=1, num_microbatches=1,
            topology=Topology(stages=1, data=1), donate=donate,
        )

    return make


def test_compiled_step_aliases_all_donated_leaves(engines):
    engine = engines(True)
    hlo = engine.compiled_step(seq_len=8).as_text()
    expected, queues = engine.donated_leaf_indices()
    res = check_donation(hlo, expected, queues)
    assert res.passed, res.detail
    # the alias map is non-trivial: params + opt moments, not just a scalar
    assert res.data["aliased"] >= len(expected) > 4


def test_undonated_step_flips_the_donation_check(engines):
    engine = engines(False)
    hlo = engine.compiled_step(seq_len=8).as_text()
    expected, queues = engine.donated_leaf_indices()
    res = check_donation(hlo, expected, queues)
    assert not res.passed
    assert len(res.data["missing"]) == min(len(expected), 32)


def test_donate_auto_resolves_per_platform(engines):
    import jax

    engine = engines("auto")
    # on the CPU test host auto is OFF (XLA:CPU aliasing serializes the
    # thunk schedule); on an accelerator it is ON
    assert engine.donate == (jax.default_backend() in ("tpu", "gpu"))


# ---------------------------------------------------------------------------
# seeded mutation through the REAL analyzer cell (subprocess: stage mesh)
# ---------------------------------------------------------------------------

MUTATION_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import json
from repro.analysis import runner
from repro.engine.spmd import SpmdEngine

def checks(cell):
    return {r.name: r.passed for r in cell}

res = {"baseline": checks(
    runner.audit_cell("1f1b", "async", "adam", "1pod")
)}

# mutation: strip donation from every engine the analyzer builds — only
# the donation check may flip
orig = SpmdEngine.__init__
def undonated(self, *a, **kw):
    kw["donate"] = False
    return orig(self, *a, **kw)
SpmdEngine.__init__ = undonated
try:
    res["undonated"] = checks(
        runner.audit_cell("1f1b", "async", "adam", "1pod")
    )
finally:
    SpmdEngine.__init__ = orig
print(json.dumps(res))
"""


def test_donation_mutation_flips_exactly_the_donation_check():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", MUTATION_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    base, mut = res["baseline"], res["undonated"]
    assert all(base.values()), base
    assert not mut["donation"]
    flipped = {k for k in base if base[k] != mut[k]}
    assert flipped == {"donation"}, (base, mut)
