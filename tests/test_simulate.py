"""Simulation-trainer mechanics: weight prediction, no-stash gradients,
sim-vs-bare-optimizer delay equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig, OptimizerConfig
from repro.data import batches
from repro.models import init_model
from repro.optim import adam, constant_schedule
from repro.optim.base import make_schedule
from repro.optim.factory import build_optimizer
from repro.pipeline.delay import delayed_optimizer
from repro.pipeline.partition import delay_tree, leaf_delays
from repro.pipeline.simulate import (
    make_two_version_loss,
    predict_weights,
    run_sim_training,
)

CFG = ModelConfig(
    num_layers=4, d_model=32, d_ff=64, vocab_size=64, max_seq_len=64,
    attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
    pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
)


def test_delay_zero_equals_no_wrapper():
    params = init_model(jax.random.PRNGKey(0), CFG)
    sched = constant_schedule(1e-3)
    bare = adam(sched)
    wrapped = delayed_optimizer(adam(sched), [0] * len(jax.tree.leaves(params)))
    g = jax.tree.map(jnp.ones_like, params)
    sb, sw = bare.init(params), wrapped.init(params)
    ub, _ = bare.update(g, sb, params, jnp.int32(0))
    uw, _ = wrapped.update(g, sw, params, jnp.int32(0))
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), ub, uw)
    assert max(jax.tree.leaves(d)) == 0.0


def test_predict_weights_moves_against_momentum():
    params = {"w": jnp.ones((4,))}
    state = {"m": {"w": jnp.ones((4,))}, "v": {"w": jnp.ones((4,))}}
    pred = predict_weights(params, state, {"w": 2}, lr=0.1)
    np.testing.assert_allclose(np.asarray(pred["w"]), 1.0 - 0.1 * 2 * 1.0, rtol=1e-5)
    # zero delay leaves weights untouched
    pred0 = predict_weights(params, state, {"w": 0}, lr=0.1)
    np.testing.assert_allclose(np.asarray(pred0["w"]), 1.0)


def test_predict_weights_rotated_state_coherent():
    """PipeMare prediction under basis rotation: m must be rotated into the
    eigenbasis before dividing by the rotated-space v, and the step rotated
    back — the old elementwise original/rotated mix is a regression."""
    n = 8
    U = jnp.asarray(np.eye(n, dtype=np.float32)[::-1].copy())  # reversal perm
    V = jnp.eye(n, dtype=jnp.float32)
    m = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n) / 10.0
    v = (jnp.arange(n * n, dtype=jnp.float32).reshape(n, n) % 7) + 1.0
    p = jnp.ones((n, n), jnp.float32)
    state = {"leaves": [{"m": m, "v": v, "U": U, "V": V}]}
    pred = predict_weights({"w": p}, state, {"w": 3}, lr=0.01)
    m_rot = U.T @ m @ V
    want = p - 0.01 * 3 * (U @ (m_rot / (jnp.sqrt(v) + 1e-8)) @ V.T)
    np.testing.assert_allclose(np.asarray(pred["w"]), np.asarray(want), rtol=1e-5)
    # the basis-mixing formula gives a different (incoherent) answer here
    mixed = p - 0.01 * 3 * m / (jnp.sqrt(v) + 1e-8)
    assert float(jnp.max(jnp.abs(pred["w"] - mixed))) > 1e-3
    # identity bases reduce to the plain Adam-style extrapolation
    eye_state = {"leaves": [{"m": m, "v": v, "U": jnp.eye(n), "V": jnp.eye(n)}]}
    pred_id = predict_weights({"w": p}, eye_state, {"w": 3}, lr=0.01)
    np.testing.assert_allclose(np.asarray(pred_id["w"]), np.asarray(mixed), rtol=1e-5)
    # non-rotated leaves (no U/V) keep the plain formula
    plain_state = {"leaves": [{"m": m, "v": v}]}
    pred_pl = predict_weights({"w": p}, plain_state, {"w": 3}, lr=0.01)
    np.testing.assert_allclose(np.asarray(pred_pl["w"]), np.asarray(mixed), rtol=1e-5)


def test_two_version_loss_gradients():
    """Same versions => identical to the plain gradient; different versions
    => a deliberately 'incorrect' gradient (no-stash pathology)."""
    from repro.models.model import loss_fn

    params = init_model(jax.random.PRNGKey(0), CFG)
    batch = next(batches(CFG, 2, 16, seed=0))
    batch = {"tokens": batch["tokens"], "labels": batch["labels"]}
    loss2w = make_two_version_loss(CFG)
    g_same = jax.grad(loss2w)(params, params, batch)
    (_, _), g_ref = jax.value_and_grad(loss_fn, has_aux=True)(params, CFG, batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g_same, g_ref)
    assert max(jax.tree.leaves(d)) < 1e-5

    older = jax.tree.map(lambda x: x * 0.9, params)
    g_mix = jax.grad(loss2w)(params, older, batch)
    d2 = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g_mix, g_ref)
    assert max(jax.tree.leaves(d2)) > 1e-4  # versions differ -> gradient differs


def test_run_sim_training_smoke_paths():
    params = init_model(jax.random.PRNGKey(0), CFG)
    ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=10)
    dt = delay_tree(params, CFG, 4)
    sched = make_schedule("cosine", 1e-3, 10, 0.1)
    for kw in (
        {},
        {"weight_prediction": True, "delays_tree": dt, "schedule": sched},
        {"no_stash": True},
    ):
        opt = build_optimizer(ocfg, params, CFG, num_stages=4)
        _, _, losses = run_sim_training(
            CFG, opt, batches(CFG, 4, 16, seed=0), steps=10, params=params, **kw
        )
        assert len(losses) == 10 and all(np.isfinite(losses))
