"""Optimizer baselines: Adam semantics, AdaSGD global scale, Nesterov
look-ahead, PipeDream-LR scaling, delay compensation, schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    ModelConfig,
    OptimizerConfig,
)
from repro.models import init_model
from repro.optim import (
    adam,
    adasgd,
    apply_updates,
    clip_by_global_norm,
    constant_schedule,
    delay_compensation,
    global_norm,
    nesterov_adam,
    pipedream_lr,
    warmup_cosine_schedule,
)
from repro.optim.factory import build_optimizer
from repro.pipeline.partition import delay_tree, leaf_delays


def test_adam_matches_manual():
    sched = constant_schedule(0.1)
    opt = adam(sched, beta1=0.9, beta2=0.99, eps=1e-8)
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    s = opt.init(p)
    u, s = opt.update(g, s, p, jnp.int32(0))
    m = 0.1 * g["w"]
    v = 0.01 * g["w"] ** 2
    want = -0.1 * (m / 0.1) / (jnp.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(np.asarray(u["w"]), np.asarray(want), rtol=1e-6)


def test_adasgd_single_scale():
    """AdaSGD scales all coordinates by the SAME denominator."""
    sched = constant_schedule(0.1)
    opt = adasgd(sched, beta1=0.0)
    p = {"a": jnp.ones((2,)), "b": jnp.ones((2,))}
    g = {"a": jnp.asarray([1.0, 1.0]), "b": jnp.asarray([100.0, 100.0])}
    s = opt.init(p)
    u, _ = opt.update(g, s, p, jnp.int32(0))
    ratio = np.asarray(u["b"]) / np.asarray(u["a"])
    np.testing.assert_allclose(ratio, 100.0, rtol=1e-5)  # no per-coord adaptivity


def test_nesterov_lookahead_differs_from_adam():
    sched = constant_schedule(0.1)
    na, ad = nesterov_adam(sched, beta1=0.9), adam(sched, beta1=0.9)
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    sn, sa = na.init(p), ad.init(p)
    un, _ = na.update(g, sn, p, jnp.int32(0))
    ua, _ = ad.update(g, sa, p, jnp.int32(0))
    assert float(jnp.max(jnp.abs(un["w"] - ua["w"]))) > 1e-8


def test_pipedream_lr_scales_with_delay():
    sched = constant_schedule(0.1)
    delays = {"a": 8, "b": 0}
    opt = pipedream_lr(sched, delays, power=0.5)
    p = {"a": jnp.ones((2,)), "b": jnp.ones((2,))}
    g = {"a": jnp.ones((2,)), "b": jnp.ones((2,))}
    s = opt.init(p)
    u, _ = opt.update(g, s, p, jnp.int32(0))
    np.testing.assert_allclose(
        np.asarray(u["a"]) * 3.0, np.asarray(u["b"]), rtol=1e-5
    )  # (1+8)^0.5 = 3


def test_pipedream_lr_per_stage_arrays():
    """Stage-stacked leaves take a (K, 1, ...) per-stage delay array: each
    stage slice of one leaf gets its own LR discount."""
    sched = constant_schedule(0.1)
    delays = {"w": jnp.asarray([[8.0], [0.0]])}  # (K=2, 1) broadcast over (2, n)
    opt = pipedream_lr(sched, delays, power=0.5)
    p = {"w": jnp.ones((2, 4))}
    g = {"w": jnp.ones((2, 4))}
    s = opt.init(p)
    u, _ = opt.update(g, s, p, jnp.int32(0))
    np.testing.assert_allclose(
        np.asarray(u["w"][0]) * 3.0, np.asarray(u["w"][1]), rtol=1e-5
    )  # (1+8)^0.5 = 3 between the two stage slices of ONE leaf


def test_delay_compensation_uses_stale_params():
    sched = constant_schedule(0.1)
    opt = delay_compensation(sched, lam=1.0, beta1=0.0, beta2=0.0)
    p = {"w": jnp.asarray([2.0])}
    stale = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([3.0])}
    s = opt.init(p)
    u_with, _ = opt.update(g, s, p, jnp.int32(0), aux={"stale_params": stale})
    s = opt.init(p)
    u_plain, _ = opt.update(g, s, p, jnp.int32(0))
    # compensated grad = 3 + 1*9*(2-1) = 12 -> differs from the plain path
    assert float(jnp.abs(u_with["w"] - u_plain["w"])[0]) >= 0.0
    # compare against manual Adam(beta=0) on compensated gradient
    comp = 3.0 + 1.0 * 9.0 * (2.0 - 1.0)
    want = -0.1 * comp / (jnp.sqrt(comp**2) + 1e-8)
    np.testing.assert_allclose(np.asarray(u_with["w"]), [want], rtol=1e-5)


def test_schedule_warmup_and_decay():
    sched = warmup_cosine_schedule(1.0, 1000, warmup_frac=0.1)
    assert float(sched(jnp.int32(0))) < 0.02
    assert abs(float(sched(jnp.int32(100))) - 1.0) < 0.02
    assert float(sched(jnp.int32(999))) < 0.01


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_factory_builds_all_and_partition_delays():
    cfg = ModelConfig(
        num_layers=4, d_model=32, d_ff=64, vocab_size=64,
        attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    delays = leaf_delays(params, cfg, 4)
    assert max(delays) == 3 and min(delays) == 0
    dt = delay_tree(params, cfg, 4)
    # embedding belongs to stage 0 => max delay; head to last => 0
    assert dt["embed"]["embedding"] == 3
    assert dt["lm_head"] == 0
    assert dt["blocks"][0]["mixer"]["w_q"] == 3
    assert dt["blocks"][3]["mixer"]["w_q"] == 0
    for name in ["adam", "adasgd", "nesterov", "pipedream_lr",
                 "delay_compensation", "basis_rotation"]:
        opt = build_optimizer(
            OptimizerConfig(name=name, total_steps=10), params, cfg, num_stages=4
        )
        s = opt.init(params)
        g = jax.tree.map(jnp.ones_like, params)
        u, s = opt.update(g, s, params, jnp.int32(0))
        assert jax.tree.structure(u) == jax.tree.structure(params)
        p2 = apply_updates(params, u)
        assert all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(p2))


def test_muon_and_scion_step():
    cfg = ModelConfig(
        num_layers=2, d_model=32, d_ff=64, vocab_size=64,
        attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    g = jax.tree.map(jnp.ones_like, params)
    for name in ("muon", "scion"):
        opt = build_optimizer(
            OptimizerConfig(name=name, total_steps=10), params, cfg, num_stages=2
        )
        s = opt.init(params)
        u, s = opt.update(g, s, params, jnp.int32(0))
        assert all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(u)), name


def test_newton_schulz_orthogonalizes():
    from repro.optim.muon import newton_schulz_orthogonalize

    G = jax.random.normal(jax.random.PRNGKey(0), (24, 16))
    O = newton_schulz_orthogonalize(G, steps=8)
    # columns approximately orthonormal: O^T O ~ I
    err = jnp.max(jnp.abs(O.T @ O - jnp.eye(16)))
    assert float(err) < 0.35  # quintic NS converges loosely by design
