"""The static analyzer must (a) pass on the healthy repo and (b) FAIL when
the invariant it guards is deliberately broken — a checker that vacuously
passes is worse than none. Seeded mutations of the real 1F1B schedule
(ungating the vocab cond, widening the input stash) and an injected f64 leaf
must each flip exactly the corresponding check."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.analysis import (
    CheckResult,
    DtypePolicy,
    check_dtype_policy,
    check_no_dot_outside_cond,
    check_scan_body_constant_in_microbatches,
    check_stash_bound,
    iter_eqns,
    leading_dims_of,
    max_float_bytes,
    n_eqns,
    vocab_dot_counts,
)
from repro.analysis.lint import (
    RULE_F64,
    RULE_SCAN_IF,
    RULE_SCAN_NP,
    check_repo_lint,
    lint_source,
)

# ---------------------------------------------------------------------------
# traversal API on small hand-built programs
# ---------------------------------------------------------------------------


def _scanned_head(vocab, gated):
    """Tiny stand-in for a tick body: a vocab-sized dot, optionally gated."""

    def body(carry, x):
        w = jnp.ones((4, vocab))

        def head(h):
            return h @ w

        def zeros(h):
            return jnp.zeros((x.shape[0], vocab))

        if gated:
            out = jax.lax.cond(carry > 0, head, zeros, x)
        else:
            out = head(x)
        return carry + 1, out.sum()

    def f(xs):
        return jax.lax.scan(body, jnp.int32(0), xs)

    return f


def test_iter_eqns_recurses_into_scan_and_cond():
    f = _scanned_head(17, gated=True)
    jx = jax.make_jaxpr(f)(jnp.ones((3, 2, 4)))
    ctxs = {ctx for _eq, ctx in iter_eqns(jx)}
    assert any("scan" in c for c in ctxs)
    assert any("scan" in c and "cond" in c for c in ctxs)
    # the walker sees strictly more equations than the top level alone
    assert n_eqns(jx) > len(jx.jaxpr.eqns)


def test_vocab_dot_counts_distinguishes_gating():
    gated = jax.make_jaxpr(_scanned_head(17, True))(jnp.ones((3, 2, 4)))
    ungated = jax.make_jaxpr(_scanned_head(17, False))(jnp.ones((3, 2, 4)))
    assert vocab_dot_counts(gated, 17) == {"outside_cond": 0, "inside_cond": 1}
    assert vocab_dot_counts(ungated, 17)["outside_cond"] >= 1
    assert check_no_dot_outside_cond(gated, 17).passed
    assert not check_no_dot_outside_cond(ungated, 17).passed
    # require_gated: a trace with no vocab dot at all must fail, not pass
    empty = jax.make_jaxpr(lambda x: x * 2)(jnp.ones((3,)))
    assert not check_no_dot_outside_cond(empty, 17, require_gated=True).passed
    assert check_no_dot_outside_cond(empty, 17, require_gated=False).passed


def test_scan_body_constant_check_and_growth_mode():
    def make(m):
        # buffer independent of m: constant program
        return jax.make_jaxpr(lambda x: jax.lax.scan(
            lambda c, t: (c + x.sum(), None), jnp.float32(0), jnp.arange(m)
        )[0])(jnp.ones((4, 4)))

    const = {m: make(m) for m in (2, 8)}
    assert check_scan_body_constant_in_microbatches(const).passed

    def make_grow(m):
        return jax.make_jaxpr(lambda x: (jnp.tile(x, (m, 1)) * 2.0).sum())(
            jnp.ones((4, 4))
        )

    grow = {m: make_grow(m) for m in (2, 8)}
    assert not check_scan_body_constant_in_microbatches(grow).passed
    assert check_scan_body_constant_in_microbatches(
        grow, expect_const_bytes=False
    ).passed
    # growth mode is non-vacuous: a constant buffer fails it
    assert not check_scan_body_constant_in_microbatches(
        const, expect_const_bytes=False
    ).passed


def test_stash_bound_on_hand_built_buffers():
    K = 3  # bound = 5
    act = (2, 8, 4)

    def prog(slots):
        return jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones((slots,) + act))

    ok = prog(2 * K - 1)
    assert check_stash_bound(ok, K, act).passed
    assert set(leading_dims_of(ok, act)) == {2 * K - 1}
    assert not check_stash_bound(prog(2 * K + 2), K, act).passed
    # a program with no stash at all is measuring the wrong thing: fail
    assert not check_stash_bound(
        jax.make_jaxpr(lambda x: x)(jnp.ones((4,))), K, act
    ).passed


# ---------------------------------------------------------------------------
# dtype policy + mutation: injected f64 leaf
# ---------------------------------------------------------------------------


def test_dtype_policy_passes_f32_and_flags_injected_f64_leaf():
    clean = jax.make_jaxpr(lambda x: (x * 2).sum())(jnp.ones((4,), jnp.float32))
    assert check_dtype_policy(clean).passed

    from jax.experimental import enable_x64

    with enable_x64():
        leaky = jax.make_jaxpr(
            lambda x: (x.astype(jnp.float64) * 2).astype(jnp.float32).sum()
        )(jnp.ones((4,), jnp.float32))
    res = check_dtype_policy(leaky)
    assert not res.passed and "float64" in res.detail
    # ONLY the dtype check flips: the same mutated program still passes the
    # structural checks it is subject to
    assert check_no_dot_outside_cond(leaky, 17, require_gated=False).passed


def test_dtype_policy_state_dtype_gate():
    bf16_in = jax.make_jaxpr(lambda x: x.sum())(jnp.ones((4,), jnp.bfloat16))
    pol = DtypePolicy(allowed_float=("float32", "bfloat16"),
                      state_dtype="float32")
    res = check_dtype_policy(bf16_in, pol)
    assert not res.passed and "state dtype" in res.detail
    # intermediates may be bf16 under the same policy
    mixed = jax.make_jaxpr(
        lambda x: (x.astype(jnp.bfloat16) * 2).astype(jnp.float32).sum()
    )(jnp.ones((4,), jnp.float32))
    assert check_dtype_policy(mixed, pol).passed


# ---------------------------------------------------------------------------
# AST lint
# ---------------------------------------------------------------------------


def test_lint_flags_each_rule_and_respects_waivers():
    src = """
import numpy as np
import jax

def tick(carry, t):
    x = np.ones(3)          # trace-time numpy inside the scan body
    if t > 0:               # Python if on a traced value
        carry = carry + 1
    return carry, None

def run(xs):
    return jax.lax.scan(tick, 0, xs)

BAD = np.float64
"""
    rules = {f.rule for f in lint_source(src)}
    assert rules == {RULE_F64, RULE_SCAN_NP, RULE_SCAN_IF}

    waived = """
import numpy as np
import jax

def tick(carry, t):
    if t > 0:               # lint: allow-traced-if
        carry = carry + 1
    return carry, None

def run(xs):
    return jax.lax.scan(jax.checkpoint(tick), 0, xs)

X = np.float64              # lint: allow-float64
"""
    assert lint_source(waived) == []
    # a non-scan function with host ifs is NOT linted
    host = """
import numpy as np

def configure(mode):
    if mode:
        return np.ones(3)
    return None
"""
    assert lint_source(host) == []


def test_repo_lint_clean():
    res = check_repo_lint()
    assert res.passed, res.detail


# ---------------------------------------------------------------------------
# seeded mutations of the REAL 1F1B schedule (subprocess: needs a stage mesh)
# ---------------------------------------------------------------------------

MUTATION_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import json
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec
from repro.engine.spmd import stack_stage_params
import repro.engine.schedules as schedules
from repro.launch.topology import Topology
from repro.models import init_model
from repro.analysis import (check_no_dot_outside_cond, check_stash_bound,
                            check_dtype_policy, check_collective_axes,
                            check_data_reduction, parse_collectives)
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

cfg = ModelConfig(num_layers=2, d_model=16, d_ff=24, vocab_size=96,
                  max_seq_len=32,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=8),
                  pattern=(BlockSpec("attn","dense"),), scan_layers=False)
K, M, S, V = 2, 2, 8, 96
topo = Topology(stages=K, data=1)
mesh = topo.make_mesh()
shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
stacked_s, shared_s = jax.eval_shape(lambda p: stack_stage_params(p, cfg, K), shapes)

def jaxpr_1f1b():
    gf = schedules.make_schedule_grad(cfg, mesh, K, M, schedule="1f1b")
    tok = jax.ShapeDtypeStruct((M, 1, S), jnp.int32)
    return jax.make_jaxpr(gf)(stacked_s, shared_s, {"tokens": tok, "labels": tok})

def run_checks(jx):
    return {
        "vocab": check_no_dot_outside_cond(jx, V, require_gated=True).to_json(),
        "stash": check_stash_bound(jx, K, (1, S, cfg.d_model)).to_json(),
        "dtype": check_dtype_policy(jx).to_json(),
    }

res = {"baseline": run_checks(jaxpr_1f1b())}

# mutation 1: delete the lax.cond vocab gate (every stage pays for the head)
orig_cond = jax.lax.cond
jax.lax.cond = lambda pred, tf, ff, *ops: tf(*ops)
try:
    res["ungated"] = run_checks(jaxpr_1f1b())
finally:
    jax.lax.cond = orig_cond

# mutation 2: widen the input stash past its 2K-1 slots
orig_slots = schedules.stash_slots
schedules.stash_slots = lambda k: 2 * k + 3
try:
    res["wide_stash"] = run_checks(jaxpr_1f1b())
finally:
    schedules.stash_slots = orig_slots

# real compiled HLO: the collective auditor accepts the actual XLA output
def f(x):
    y = jax.lax.pmean(x, "data")
    z = jax.lax.psum(x, "stage")
    w = jax.lax.ppermute(x, "stage", [(0, 1)])
    return y + z + w
sm = shard_map(f, mesh=mesh, in_specs=P("stage"), out_specs=P("stage"),
               check_rep=False)
hlo = jax.jit(sm).lower(jnp.zeros((2, 4))).compile().as_text()
instrs = parse_collectives(hlo)
res["hlo"] = {
    "n_collectives": len(instrs),
    "axes": check_collective_axes(instrs, topo).to_json(),
    "data_red": check_data_reduction(instrs, topo).to_json(),
}
print(json.dumps(res))
"""


def test_seeded_mutations_flip_exactly_their_check():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", MUTATION_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])

    base = res["baseline"]
    assert base["vocab"]["passed"] and base["stash"]["passed"] \
        and base["dtype"]["passed"], base

    # ungating flips ONLY the vocab-dot check
    mut = res["ungated"]
    assert not mut["vocab"]["passed"], mut
    assert mut["vocab"]["data"]["outside_cond"] >= 1, mut
    assert mut["stash"]["passed"] and mut["dtype"]["passed"], mut

    # widening the stash flips ONLY the stash-bound check
    mut = res["wide_stash"]
    assert not mut["stash"]["passed"], mut
    assert 2 * 2 + 3 in mut["stash"]["data"]["slot_counts"], mut
    assert mut["vocab"]["passed"] and mut["dtype"]["passed"], mut

    # the collective auditor parses and accepts real optimized XLA output
    hlo = res["hlo"]
    assert hlo["n_collectives"] >= 2, hlo
    assert hlo["axes"]["passed"], hlo
    assert hlo["data_red"]["passed"], hlo


# ---------------------------------------------------------------------------
# the matrix runner end to end (jaxpr checks, one optimizer column)
# ---------------------------------------------------------------------------


def test_runner_smoke_matrix_adam_column(tmp_path):
    out_path = str(tmp_path / "report.json")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--matrix", "smoke",
         "--optimizers", "adam", "--no-hlo", "--out", out_path],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        env={**env, "PYTHONPATH": "src"}, timeout=1800,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    report = json.loads(open(out_path).read())
    assert report["passed"], report
    # 2 schedules x 2 sync modes x 1 optimizer x 2 topologies
    assert len(report["cells"]) == 8, [c["checks"] for c in report["cells"]]
    assert len(report["scaling"]) == 4
    assert report["lint"]["passed"], report["lint"]
    for cell in report["cells"]:
        names = {c["name"] for c in cell["checks"]}
        assert "dtype_policy" in names and "no_dot_outside_cond" in names
        if cell["schedule"] == "1f1b":
            assert "stash_bound" in names
