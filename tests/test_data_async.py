"""Asynchronous data axis: deferred cross-replica gradient reduction.

Unit tests cover the `StageContext` total-delay accounting (pipeline tau +
data delay), the delay-aware Nesterov optimizer's closed-form look-ahead,
the param-queue deepening of the stage FIFO wrapper, the sim backend's
composed FIFO depths, and the paired step/reduce analyzer check on
synthetic collective instructions.

The subprocess tests (forced 4-device host, like tests/test_donation.py)
drive the REAL `SpmdEngine`: D=0 bitwise parity with the synchronous path,
D=2 equivalence against a hand-rolled per-step reference reduction pushed
through a python FIFO, bitwise mid-run checkpoint resume including the
in-flight reduction FIFO, HLO placement of the data all-reduce, and a
seeded mutation that swaps the async step program for a synchronous one
and must flip exactly the two deferred-reduction checks. The spawn test at
the bottom kills and resumes a REAL 2-process async-data run from its
sharded checkpoint.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import (
    CollectiveInstr,
    check_async_step_reduction,
    check_data_reduction,
)
from repro.configs.base import AttentionConfig, ModelConfig, OptimizerConfig
from repro.core.stage_aware import StageContext
from repro.launch.topology import Topology
from repro.models.model import init_model
from repro.optim.adam import nesterov_adam
from repro.optim.base import Optimizer, make_schedule
from repro.optim.delay_aware import nesterov_pp
from repro.optim.factory import build_optimizer
from repro.pipeline.delay import stage_delayed_optimizer
from repro.pipeline.partition import stage_context_for_tree

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TINY = ModelConfig(
    num_layers=2, d_model=16, d_ff=24, vocab_size=96, max_seq_len=32,
    scan_layers=False,
    attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=8),
)


# -- StageContext: data delay is accounted, not queued ----------------------

def test_stage_context_data_delay_accounting():
    delays = ((3, 2, 1, 0), 3, 0)
    repeats = (2, 1, 1)
    ctx0 = StageContext(num_stages=4, delays=delays, repeats=repeats)
    ctxD = StageContext(num_stages=4, delays=delays, repeats=repeats,
                        data_delay=2)

    # FIFO depth specs are PIPELINE-only: the data delay is imposed by the
    # engine's deferred-reduction FIFO, not by deeper stage queues
    assert ctxD.delay_specs() == ctx0.delay_specs() == ["stage", 3, 0]

    # ...but every consumer of the delay VALUE sees the total tau + D
    params = [jnp.zeros((4, 2, 3)), jnp.zeros((5,)), jnp.zeros((7,))]
    for a, b in zip(jax.tree.leaves(ctx0.delay_scales(params)),
                    jax.tree.leaves(ctxD.delay_scales(params))):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a) + 2)

    # refresh allocation runs on the total delay: shifting every leaf by D
    # equals building the context with pre-shifted pipeline delays
    shifted = StageContext(num_stages=4, delays=((5, 4, 3, 2), 5, 2),
                           repeats=repeats)
    assert ctxD.refresh_freqs(8) == shifted.refresh_freqs(8)


# -- Nesterov async-PP optimizer (Ajanthan et al. 2505.01099) ---------------

def test_nesterov_pp_zero_delay_is_nesterov_adam():
    sched = make_schedule("constant", 1e-2, 100, 0.0)
    params = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([[0.5, 1.5]])}
    a = nesterov_adam(sched, 0.99, 0.999, 1e-8)
    b = nesterov_pp(sched, jax.tree.map(lambda p: 0, params), 0.99, 0.999,
                    1e-8)
    sa, sb = a.init(params), b.init(params)
    for t in range(3):
        g = jax.tree.map(lambda p: jnp.sin(p + t), params)
        ua, sa = a.update(g, sa, params, jnp.int32(t))
        ub, sb = b.update(g, sb, params, jnp.int32(t))
        for x, y in zip(jax.tree.leaves(ua), jax.tree.leaves(ub)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6)


def test_nesterov_pp_closed_form_look_ahead():
    lr, beta1, beta2, eps = 1e-2, 0.9, 0.999, 1e-8
    sched = make_schedule("constant", lr, 10, 0.0)
    p = {"w": jnp.array([1.0, -0.5])}
    g = {"w": jnp.array([0.3, 0.2])}

    def first_update(tau):
        opt = nesterov_pp(sched, {"w": tau}, beta1, beta2, eps)
        u, _ = opt.update(g, opt.init(p), p, jnp.int32(0))
        return np.asarray(u["w"])

    # one step from zero moments: m = (1-b1) g, v = (1-b2) g^2, and the
    # look-ahead collapses to n = b1^(tau+1) m + (1 - b1^(tau+1)) g
    gw = np.asarray(g["w"])
    m, v = (1 - beta1) * gw, (1 - beta2) * gw**2
    for tau in (0, 1, 3):
        look = beta1 ** (tau + 1)
        n = look * m + (1 - look) * gw
        want = -lr * (n / (1 - beta1)) / (np.sqrt(v / (1 - beta2)) + eps)
        np.testing.assert_allclose(first_update(tau), want, rtol=3e-5)

    # stage-stacked leaf with per-stage horizons: each row must match the
    # scalar-delay computation for that row's tau
    p2 = {"w": jnp.stack([p["w"], p["w"]])}
    g2 = {"w": jnp.stack([g["w"], g["w"]])}
    opt = nesterov_pp(sched, {"w": jnp.array([[1.0], [3.0]])}, beta1, beta2,
                      eps)
    u2, _ = opt.update(g2, opt.init(p2), p2, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(u2["w"][0]), first_update(1),
                               rtol=3e-5)
    np.testing.assert_allclose(np.asarray(u2["w"][1]), first_update(3),
                               rtol=3e-5)


# -- stage FIFO wrapper: extra_param_delay deepens only the param queues ----

def test_stage_fifo_extra_param_delay_snapshots():
    K, STEPS = 2, 5
    base = np.array([[10.0], [20.0]])
    captured = []

    def _update(grads, state, params, step, aux=None):
        captured.append((np.asarray(jax.tree.leaves(grads)[0]),
                         np.asarray(jax.tree.leaves(aux["stale_params"])[0])))
        return jax.tree.map(jnp.zeros_like, grads), state

    def run(E):
        captured.clear()
        opt = stage_delayed_optimizer(Optimizer(lambda p: {}, _update),
                                      ["stage"], K, store_params=True,
                                      extra_param_delay=E)
        state = opt.init([jnp.asarray(base)])
        for t in range(STEPS):
            _, state = opt.update([jnp.full((K, 1), float(t + 1))], state,
                                  [jnp.asarray(base) + t], jnp.int32(t))
        return list(captured)

    runs = {E: run(E) for E in (0, 1, 2)}
    for E, got in runs.items():
        for t, (gstale, pstale) in enumerate(got):
            # grad queues are pipeline-depth regardless of E: stage k sees
            # g_{t - (K-1-k)}, zeros during warm-up
            want_g = np.array([[float(t + 1 - (K - 1 - k))
                                if t - (K - 1 - k) >= 0 else 0.0]
                               for k in range(K)])
            np.testing.assert_array_equal(gstale, want_g, err_msg=f"E={E} t={t}")
            # param queues carry the TOTAL delay: stage k sees
            # w_{t - (K-1-k+E)}, clamped to the warm-start snapshot w_0
            want_p = np.stack([base[k] + max(0, t - (K - 1 - k + E))
                               for k in range(K)])
            np.testing.assert_array_equal(pstale, want_p, err_msg=f"E={E} t={t}")


# -- sim backend: data_delay composes into the per-leaf FIFO depths ---------

def test_build_optimizer_sim_data_delay_deepens_grad_fifo():
    params = init_model(jax.random.PRNGKey(0), TINY)
    ocfg = OptimizerConfig(name="adam", total_steps=10)

    def depths(data_delay, num_stages=2):
        opt = build_optimizer(ocfg, params, TINY, num_stages=num_stages,
                              data_delay=data_delay)
        st = opt.init(params)
        return [0 if q is None else int(q.shape[0]) for q in st["grad_q"]]

    base_specs = [int(d) for d in
                  stage_context_for_tree(params, TINY, 2).delay_specs()]
    d0 = depths(0)
    assert d0 == base_specs
    # D=2: every leaf's FIFO is exactly 2 deeper — the deferred reduction
    # delays ALL leaves uniformly, on top of the pipeline stage delay
    assert depths(2) == [d + 2 for d in d0]
    # single-stage still wraps when D > 0 (pure data-axis staleness)...
    assert depths(2, num_stages=1) == [2] * len(d0)
    # ...and D=0 single-stage builds the bare optimizer, no FIFO state
    bare = build_optimizer(ocfg, params, TINY, num_stages=1)
    assert "grad_q" not in bare.init(params)


def test_sim_engine_data_delay_zero_bitwise():
    """--data-delay 0 on the sim backend is the SAME program as no flag at
    all (the spmd counterpart lives in the subprocess test below)."""
    from repro.data import batches
    from repro.engine import LoopConfig, SimEngine, run_loop

    ocfg = OptimizerConfig(name="adam", total_steps=4)
    params = init_model(jax.random.PRNGKey(0), TINY)

    def losses(**kw):
        opt = build_optimizer(ocfg, params, TINY, num_stages=2, **kw)
        engine = SimEngine(TINY, opt)
        state = engine.init_state(params=params)
        _, ls = run_loop(engine, batches(TINY, 4, 16, seed=0),
                         LoopConfig(steps=4), state=state)
        return ls

    assert losses(data_delay=0) == losses()


# -- analyzer: paired step/reduce placement check ---------------------------

def _data_all_reduce(topo):
    return CollectiveInstr(op="all-reduce", out_bytes=128,
                           replica_groups=topo.replica_groups(topo.data_axes))


def test_check_data_reduction_deferred_mode():
    topo = Topology(stages=2, data=2)
    ar = _data_all_reduce(topo)
    # sync contract: the data all-reduce must be IN the step
    assert check_data_reduction([ar], topo).passed
    assert not check_data_reduction([], topo).passed
    # deferred contract inverts the first half: it must NOT be in the step
    assert not check_data_reduction([ar], topo, deferred=True).passed
    r = check_data_reduction([], topo, deferred=True)
    assert r.passed and r.data["deferred"]


def test_check_async_step_reduction_pairing():
    topo = Topology(stages=2, data=2)
    ar = _data_all_reduce(topo)
    assert check_async_step_reduction([], [ar], topo).passed
    # back on the critical path -> fail, whatever the reduce program holds
    assert not check_async_step_reduction([ar], [ar], topo).passed
    # vanished instead of deferred -> fail: the reduction must still happen
    r = check_async_step_reduction([], [], topo)
    assert not r.passed and r.data["required_in_reduce"]
    # single data shard: deferred reduction is the identity, nothing required
    assert check_async_step_reduction([], [], Topology(stages=2)).passed


# -- launcher flag validation (before any heavy work) -----------------------

@pytest.mark.parametrize("argv", [
    ["--data-delay", "1"],                  # delay without --data-async
    ["--data-async", "--data-delay", "-1"],  # negative delay
    ["--data-async", "--sync"],             # contradictory modes
])
def test_train_data_async_flag_validation(argv):
    from repro.launch import train

    with pytest.raises(SystemExit):
        train.main(argv + ["--smoke", "--steps", "1"])


# -- subprocess: real SpmdEngine equivalence + checkpoint resume ------------

EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys, tempfile
sys.path.insert(0, "src")

import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import AttentionConfig, ModelConfig, OptimizerConfig
from repro.engine.spmd import SpmdEngine
from repro.launch.topology import Topology
from repro.models.model import init_model
from repro.optim.base import apply_updates, clip_by_global_norm

cfg = ModelConfig(num_layers=2, d_model=16, d_ff=24, vocab_size=96,
                  max_seq_len=32, scan_layers=False,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2,
                                            head_dim=8))
ocfg = OptimizerConfig(name="adam", total_steps=20)
topo = Topology(stages=2, data=2)
K, M, B, S, STEPS, D = 2, 2, 4, 8, 5, 2

params = init_model(jax.random.PRNGKey(0), cfg)
bs = []
for k in jax.random.split(jax.random.PRNGKey(7), STEPS):
    tok = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    bs.append({"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)})

def run(engine, t0=0, state=None):
    st = engine.init_state(params=params) if state is None else state
    losses = []
    for t in range(t0, STEPS):
        st, loss, _ = engine.step(st, bs[t], t)
        losses.append(float(loss))
    return st, losses

for sched in ("fill_drain", "1f1b"):
    e_sync = SpmdEngine(cfg, ocfg, K, M, schedule=sched, topology=topo,
                        donate=False)
    e_d0 = SpmdEngine(cfg, ocfg, K, M, schedule=sched, topology=topo,
                      donate=False, data_async=True, data_delay=0)
    st_s, l_s = run(e_sync)
    st_0, l_0 = run(e_d0)
    # --data-delay 0 is BITWISE the synchronous path
    assert l_s == l_0, (sched, l_s, l_0)
    for a, b in zip(jax.tree.leaves(st_s.params), jax.tree.leaves(st_0.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # D=2 against a hand-rolled reference: per-step reference reduction
    # (the sync grad_fn) pushed through a python FIFO of depth D
    e_a = SpmdEngine(cfg, ocfg, K, M, schedule=sched, topology=topo,
                     donate=False, data_async=True, data_delay=D)
    st_a, l_a = run(e_a)
    stacked, shared = e_sync.init_state(params=params).params
    opt_state = e_a.opt.init((stacked, shared))
    fifo = [e_a._zero_gbar()] * D
    ref_losses = []
    for t in range(STEPS):
        loss, grads = e_sync.grad_fn(stacked, shared,
                                     e_sync._shape_batch(dict(bs[t])))
        ref_losses.append(float(loss))
        fifo.append(grads)
        g = clip_by_global_norm(fifo.pop(0), 1.0)
        updates, opt_state = e_a.opt.update(g, opt_state, (stacked, shared),
                                            jnp.int32(t))
        stacked = apply_updates(stacked, updates[0])
        shared = apply_updates(shared, updates[1])
    np.testing.assert_allclose(np.asarray(l_a), np.asarray(ref_losses),
                               rtol=2e-5, atol=1e-6)
    # f32, different all-reduce orderings between the shard_map reduce and
    # the replicated-reference mean: per-element noise up to a few e-4
    for a, b in zip(jax.tree.leaves(st_a.params),
                    jax.tree.leaves((stacked, shared))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=2e-5)

    # HLO placement: zero data-grouped all-reduces in the step program,
    # at least one in the deferred reduce program
    from repro.analysis.hlo import parse_collectives, _instr_grouping, _normalize
    want = _normalize(topo.replica_groups(topo.data_axes))
    n_step = sum(1 for i in parse_collectives(e_a.compiled_step().as_text())
                 if i.op == "all-reduce" and _instr_grouping(i, topo) == want)
    n_red = sum(1 for i in parse_collectives(e_a.compiled_reduce().as_text())
                if i.op == "all-reduce" and _instr_grouping(i, topo) == want)
    assert n_step == 0 and n_red >= 1, (sched, n_step, n_red)

# mid-run checkpoint -> resume must be bitwise, INCLUDING the in-flight
# reduction FIFO (saved as the checkpoint tree's third element)
from repro.checkpoint import load_checkpoint
e_a = SpmdEngine(cfg, ocfg, K, M, schedule="1f1b", topology=topo,
                 donate=False, data_async=True, data_delay=D)
st = e_a.init_state(params=params)
full = []
with tempfile.TemporaryDirectory() as tmp:
    path = os.path.join(tmp, "ck")
    for t in range(STEPS):
        st, loss, _ = e_a.step(st, bs[t], t)
        full.append(float(loss))
        if t == 1:
            e_a.save_checkpoint(path, st, step=t + 1)
    tree, step0, _ = load_checkpoint(path)
    st2 = e_a.load_state(tree)
    assert len(st2.data_fifo) == D
    resumed = []
    for t in range(step0, STEPS):
        st2, loss, _ = e_a.step(st2, bs[t], t)
        resumed.append(float(loss))
assert resumed == full[step0:], (resumed, full[step0:])
for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(st2.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# an async engine refuses a FIFO of the wrong depth
bad = SpmdEngine(cfg, ocfg, K, M, schedule="1f1b", topology=topo,
                 donate=False, data_async=True, data_delay=D + 1)
try:
    bad.load_state((st.params, st.opt_state, tuple(st.data_fifo)))
except ValueError:
    pass
else:
    raise AssertionError("depth mismatch must raise")
# ...but warm-starts from a synchronous 2-tuple with a zero FIFO
warm = bad.load_state((st.params, st.opt_state))
assert len(warm.data_fifo) == D + 1

print("DATA_ASYNC_EQUIV_OK")
"""


def _run_script(script, timeout=900):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, cwd=REPO, env=env, timeout=timeout)


def test_spmd_data_async_equivalence_and_resume():
    out = _run_script(EQUIV_SCRIPT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DATA_ASYNC_EQUIV_OK" in out.stdout


# -- seeded mutation: the analyzer pair must catch a sync step --------------

MUTATION_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, "src")
import json

from repro.analysis import runner
from repro.engine.spmd import SpmdEngine

def checks(cell):
    return {r.name: r.passed for r in cell}

res = {"baseline": checks(runner.audit_data_async_cell("1f1b", "adam", "2data"))}

# mutation: hand the auditor a SYNCHRONOUS step program posing as the async
# one — the (pod, data) all-reduce is back on the critical path, and only
# the deferred-reduction pair of checks may notice (donation and
# collective_axes must stay green: same donated triple, declared axes)
orig = SpmdEngine.compiled_step
def sync_posing_as_async(self, seq_len=8, microbatch_size=0):
    sync = SpmdEngine(self.cfg, runner._opt_cfg("adam"),
                      num_stages=self.num_stages,
                      num_microbatches=self.num_microbatches,
                      async_grads=True, schedule=self.schedule,
                      topology=self.topology, donate=True)
    return orig(sync, seq_len, microbatch_size)

SpmdEngine.compiled_step = sync_posing_as_async
try:
    res["mutated"] = checks(runner.audit_data_async_cell("1f1b", "adam", "2data"))
finally:
    SpmdEngine.compiled_step = orig
print(json.dumps(res))
"""


def test_async_reduction_checks_catch_sync_step_mutation():
    out = _run_script(MUTATION_SCRIPT)
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    base, mut = res["baseline"], res["mutated"]
    assert all(base.values()), base
    flipped = {k for k in base if base[k] != mut[k]}
    assert flipped == {"data_reduction", "async_data_reduction"}, (base, mut)


# -- multi-process spawn: async-data run resumes from a sharded ckpt --------

TRAIN_ARGS = ("--backend spmd --smoke --arch paper_95m --optimizer adam "
              "--batch 4 --seq 32 --lr 1e-3 --log-every 2 --steps 8 "
              "--ckpt-every 4 --stages 2 --data-par 2 "
              "--data-async --data-delay 1")


def _spawn(extra, train_args, timeout=840):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.spawn", *extra, "--",
           *train_args.split()]
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(cmd, capture_output=True, text=True, cwd=REPO,
                          env=env, timeout=timeout)


def test_spawn_async_data_bitwise_resume_from_sharded_ckpt(tmp_path):
    """2-process (stages=2, data=2) --data-async --data-delay 1 run: kill a
    process after the step-4 checkpoint commits, relaunch the same
    topology, and the merged metrics must equal the uninterrupted run's bit
    for bit — the sharded checkpoint round-trips the reduction FIFO."""
    ref_out = str(tmp_path / "ref.json")
    out = _spawn(["--procs", "2", "--timeout", "780"],
                 f"{TRAIN_ARGS} --out {ref_out}")
    assert out.returncode == 0, out.stderr[-3000:]
    ref = json.load(open(ref_out))
    assert ref["data_async"] and ref["data_delay"] == 1
    assert len(ref["losses"]) == 8

    ckpt = str(tmp_path / "ckpt")
    res_out = str(tmp_path / "res.json")
    run_args = f"{TRAIN_ARGS} --ckpt-dir {ckpt} --out {res_out}"
    out = _spawn(["--procs", "2", "--timeout", "780", "--kill-pod-at", "4",
                  "--grace", "8", "--resume-procs", "2",
                  "--resume-with", run_args],
                 run_args)
    assert out.returncode == 0, out.stderr[-3000:]

    res = json.load(open(res_out))
    assert res["steps_done"] == 8 and res["start_step"] == 0
    assert res["losses"] == ref["losses"], (res["losses"], ref["losses"])
