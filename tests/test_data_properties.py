"""Property-based tests for the sharded data loaders (hypothesis).

Skipped wholesale when hypothesis is not installed (the CI image does not
ship it); the deterministic parametrized versions of the same invariants run
unconditionally in test_infra.py. Kept in a separate module so the skip
never hides unrelated data tests.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.data import batches, process_local_batches, sharded_batches  # noqa: E402

CFG = ModelConfig(vocab_size=64)
B, S = 8, 16


def divisors(n):
    return [d for d in range(1, n + 1) if n % d == 0]


@st.composite
def host_splits(draw):
    num_hosts = draw(st.sampled_from(divisors(B)))
    host_id = draw(st.integers(0, num_hosts - 1))
    prefix = draw(st.integers(0, 3))
    return num_hosts, host_id, prefix


@settings(max_examples=15, deadline=None)
@given(host_splits())
def test_sharded_batches_partition_and_resume(split):
    """Any (num_hosts, host_id) dividing the batch: the host slice is the
    corresponding rows of the global stream, and fast-forwarding a fresh
    iterator `prefix` steps (the resume path) lands on the same batch the
    uninterrupted host stream yields."""
    num_hosts, host_id, prefix = split
    local = B // num_hosts
    lo, hi = host_id * local, (host_id + 1) * local

    ref = batches(CFG, B, S, seed=9)
    it = sharded_batches(CFG, B, S, num_hosts, host_id, seed=9)
    seen = []
    for _ in range(prefix + 1):
        want, got = next(ref), next(it)
        seen.append(got)
        for key in want:
            np.testing.assert_array_equal(
                np.asarray(got[key]), np.asarray(want[key])[lo:hi]
            )
    fresh = sharded_batches(CFG, B, S, num_hosts, host_id, seed=9)
    for _ in range(prefix):
        next(fresh)
    resumed = next(fresh)
    for key in resumed:
        np.testing.assert_array_equal(
            np.asarray(resumed[key]), np.asarray(seen[prefix][key])
        )


@st.composite
def shard_ranges(draw):
    M = draw(st.sampled_from([1, 2, 4]))
    data_shards = draw(st.sampled_from(divisors(B // M)))
    lo = draw(st.integers(0, data_shards - 1))
    hi = draw(st.integers(lo + 1, data_shards))
    return M, data_shards, lo, hi


@settings(max_examples=15, deadline=None)
@given(shard_ranges())
def test_process_local_batches_slice_of_global_reshape(r):
    """Any contiguous [lo, hi) shard range: the process-local stream equals
    the matching slice of the global (M, shards, w, S) reshape bit-for-bit."""
    M, data_shards, lo, hi = r
    w = B // M // data_shards
    ref = batches(CFG, B, S, seed=2)
    it = process_local_batches(CFG, B, S, num_microbatches=M,
                               data_shards=data_shards, shard_lo=lo,
                               shard_hi=hi, seed=2)
    for _ in range(2):
        want, got = next(ref), next(it)
        for key in want:
            glob = np.asarray(want[key]).reshape(M, data_shards, w, -1)
            np.testing.assert_array_equal(
                np.asarray(got[key]).reshape(M, hi - lo, w, -1),
                glob[:, lo:hi],
            )
