"""Unified pipeline engine: sim backend reproduces the pre-refactor trainer
bit-for-bit, the per-stage FIFO wrapper matches exact PipeDream delays, the
loop checkpoints/resumes, and (subprocess — needs a multi-device fake
topology) the sim and SPMD backends agree in the synchronous-gradient case."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    ModelConfig,
    OptimizerConfig,
)
from repro.data import batches
from repro.engine import LoopConfig, SimEngine, run_loop
from repro.engine.loop import resume_if_present
from repro.models import init_model
from repro.optim.base import Optimizer
from repro.optim.factory import build_optimizer
from repro.pipeline.delay import delayed_optimizer, stage_delayed_optimizer
from repro.pipeline.partition import delay_tree
from repro.pipeline.simulate import make_sim_train_step, stale_forward_params

CFG = ModelConfig(
    num_layers=4, d_model=32, d_ff=64, vocab_size=64, max_seq_len=64,
    attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
    pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
)


def _pre_refactor_losses(cfg, opt, data_iter, steps, params, no_stash=False,
                         delays_tree=None):
    """Verbatim port of the pre-engine `run_sim_training` body (the reference
    the refactor must reproduce bit-for-bit)."""
    opt_state = opt.init(params)
    step_fn = make_sim_train_step(cfg, opt, 1.0, False, delays_tree, None, no_stash)
    max_age = 0
    if no_stash and delays_tree is not None:
        max_age = max(int(d) for d in jax.tree_util.tree_leaves(delays_tree))
    history, losses = [], []
    for t in range(steps):
        batch = next(data_iter)
        fwd_hist = (
            stale_forward_params(history, params, delays_tree) if no_stash else 0
        )
        params, opt_state, loss, _ = step_fn(
            params, opt_state, fwd_hist, batch, jnp.int32(t)
        )
        if no_stash and max_age:
            history.append(params)
            history = history[-(max_age + 1):]
        losses.append(float(loss))
    return losses


def test_sim_backend_matches_pre_refactor_bitwise():
    steps = 8
    params = init_model(jax.random.PRNGKey(0), CFG)
    ocfg = OptimizerConfig(name="basis_rotation", learning_rate=3e-3,
                           total_steps=steps, rotation_freq=3)

    ref = _pre_refactor_losses(
        CFG, build_optimizer(ocfg, params, CFG, num_stages=4),
        batches(CFG, 8, 16, seed=0), steps, params,
    )
    engine = SimEngine(CFG, build_optimizer(ocfg, params, CFG, num_stages=4))
    state = engine.init_state(params=params)
    _, got = run_loop(engine, batches(CFG, 8, 16, seed=0),
                      LoopConfig(steps=steps), state=state)
    assert got == ref  # bit-for-bit, not approximately


def test_sim_backend_no_stash_history_matches_pre_refactor():
    steps = 8
    params = init_model(jax.random.PRNGKey(0), CFG)
    dtree = delay_tree(params, CFG, 4)
    ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=steps)

    ref = _pre_refactor_losses(
        CFG, build_optimizer(ocfg, params, CFG, num_stages=4),
        batches(CFG, 8, 16, seed=0), steps, params,
        no_stash=True, delays_tree=dtree,
    )
    engine = SimEngine(
        CFG, build_optimizer(ocfg, params, CFG, num_stages=4),
        delays_tree=dtree, no_stash=True,
    )
    state = engine.init_state(params=params)
    _, got = run_loop(engine, batches(CFG, 8, 16, seed=0),
                      LoopConfig(steps=steps), state=state)
    assert got == ref


def test_stage_delayed_optimizer_exact_pipedream_delays():
    """The diagonal-FIFO read gives stage k the gradient from exactly
    tau_k = K-1-k steps ago — identical to per-leaf FIFOs on the slices."""
    K, n = 4, 3
    identity = Optimizer(
        init=lambda p: {}, update=lambda g, s, p, t, aux=None: (g, s)
    )
    stacked = jnp.zeros((K, n))
    shared = {"embed": jnp.zeros((n,)), "lm_head": jnp.zeros((n,))}
    specs = ["stage", K - 1, 0]  # tree_flatten order: stacked, embed, lm_head
    opt = stage_delayed_optimizer(identity, specs, K)
    state = opt.init((stacked, shared))

    # reference: one per-stage FIFO per slice via the sim wrapper
    ref_opt = delayed_optimizer(
        identity, [K - 1 - k for k in range(K)] + [K - 1, 0]
    )
    ref_state = ref_opt.init(
        (tuple(stacked[k] for k in range(K)), shared)
    )

    for t in range(8):
        g_stacked = jnp.stack(
            [jnp.full((n,), 100.0 * t + k) for k in range(K)]
        )
        g_shared = {"embed": jnp.full((n,), 100.0 * t - 1),
                    "lm_head": jnp.full((n,), 100.0 * t - 2)}
        (u_stacked, u_shared), state = opt.update(
            (g_stacked, g_shared), state, (stacked, shared), jnp.int32(t)
        )
        (ur_stacked, ur_shared), ref_state = ref_opt.update(
            (tuple(g_stacked[k] for k in range(K)), g_shared),
            ref_state,
            (tuple(stacked[k] for k in range(K)), shared),
            jnp.int32(t),
        )
        for k in range(K):
            np.testing.assert_array_equal(
                np.asarray(u_stacked[k]), np.asarray(ur_stacked[k]),
                err_msg=f"stage {k} at step {t}",
            )
            # explicit semantics: stage k sees g from t - (K-1-k), zeros before
            tau = K - 1 - k
            want = 100.0 * (t - tau) + k if t >= tau else 0.0
            np.testing.assert_allclose(np.asarray(u_stacked[k]), want)
        np.testing.assert_array_equal(
            np.asarray(u_shared["embed"]), np.asarray(ur_shared["embed"])
        )
        np.testing.assert_array_equal(
            np.asarray(u_shared["lm_head"]), np.asarray(ur_shared["lm_head"])
        )


def test_stage_delayed_optimizer_stale_param_snapshots():
    """``store_params=True``: stage k's stale-weight snapshot is its own
    slice from exactly tau_k steps ago (initial weights during warm-up) —
    identical to per-slice FIFOs with ``delayed_optimizer(store_params)``."""
    K, n = 4, 3
    seen, ref_seen = [], []
    probe = Optimizer(
        init=lambda p: {},
        update=lambda g, s, p, t, aux=None: (seen.append(aux["stale_params"]) or (g, s)),
    )
    ref_probe = Optimizer(
        init=lambda p: {},
        update=lambda g, s, p, t, aux=None: (ref_seen.append(aux["stale_params"]) or (g, s)),
    )
    stacked0 = jnp.arange(K * n, dtype=jnp.float32).reshape(K, n)
    shared0 = {"embed": jnp.zeros((n,)), "lm_head": jnp.zeros((n,))}
    specs = ["stage", K - 1, 0]
    opt = stage_delayed_optimizer(probe, specs, K, store_params=True)
    ref = delayed_optimizer(ref_probe, [K - 1 - k for k in range(K)] + [K - 1, 0],
                            store_params=True)
    state = opt.init((stacked0, shared0))
    ref_state = ref.init((tuple(stacked0[k] for k in range(K)), shared0))
    stacked, shared = stacked0, shared0
    for t in range(7):
        g = (jnp.full((K, n), float(t)), {"embed": jnp.zeros((n,)),
                                          "lm_head": jnp.zeros((n,))})
        _, state = opt.update(g, state, (stacked, shared), jnp.int32(t))
        _, ref_state = ref.update(
            (tuple(g[0][k] for k in range(K)), g[1]), ref_state,
            (tuple(stacked[k] for k in range(K)), shared), jnp.int32(t),
        )
        got, want = seen[-1], ref_seen[-1]
        for k in range(K):
            np.testing.assert_array_equal(
                np.asarray(got[0][k]), np.asarray(want[0][k]),
                err_msg=f"stage {k} step {t}",
            )
            # explicit semantics: stage k sees w from t - tau_k (w0 in warmup)
            tau = K - 1 - k
            exp = stacked0[k] + max(t - tau, 0)
            np.testing.assert_allclose(np.asarray(got[0][k]), np.asarray(exp))
        np.testing.assert_array_equal(np.asarray(got[1]["embed"]),
                                      np.asarray(want[1]["embed"]))
        # advance params deterministically so snapshots are distinguishable
        stacked = stacked + 1.0


def test_loop_checkpoint_resume_and_metrics(tmp_path):
    steps = 6
    ckpt = str(tmp_path / "ckpt")
    out = str(tmp_path / "m.json")
    params = init_model(jax.random.PRNGKey(0), CFG)
    ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=steps)

    def make_engine():
        return SimEngine(CFG, build_optimizer(ocfg, params, CFG, num_stages=1))

    cfg = LoopConfig(steps=3, ckpt_dir=ckpt, ckpt_every=3, out_path=out,
                     out_meta={"arch": "t"})
    engine = make_engine()
    state = engine.init_state(params=params)
    state, first = run_loop(engine, batches(CFG, 4, 16, seed=0), cfg, state=state)
    assert json.loads(open(out).read())["steps_done"] == 3

    # resume from the checkpoint: resume_if_present fast-forwards the data
    # stream itself, so the continuation consumes batches 3.. like the
    # uninterrupted run
    engine2 = make_engine()
    state2 = engine2.init_state(params=params)
    data = batches(CFG, 4, 16, seed=0)
    state2, start = resume_if_present(engine2, state2, ckpt, data)
    assert start == 3
    _, rest = run_loop(engine2, data,
                       LoopConfig(steps=steps, out_path=out, out_meta={"arch": "t"}),
                       state=state2, start_step=start)
    assert len(rest) == 3

    # the resumed metrics file merges the pre-resume series: full absolute-
    # step loss curve, honest steps_done
    m = json.loads(open(out).read())
    assert m["steps_done"] == 6 and m["start_step"] == 0
    assert m["losses"] == first + rest

    # uninterrupted reference: the interrupt must be invisible — bit-identical
    engine3 = make_engine()
    state3 = engine3.init_state(params=params)
    _, full = run_loop(engine3, batches(CFG, 4, 16, seed=0),
                       LoopConfig(steps=steps), state=state3)
    assert first + rest == full  # exact, not approximate


def test_async_io_parity_with_inline_writes(tmp_path):
    """`async_io=True` (background writer) and `False` (inline) must leave
    bit-identical results: same losses, same metrics JSON, same checkpoint.
    The writer is drained before run_loop returns, so nothing is in flight
    when the comparison runs."""
    steps = 5
    params = init_model(jax.random.PRNGKey(0), CFG)
    ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=steps)
    outs = {}
    for mode in (True, False):
        ckpt = str(tmp_path / f"ckpt_{mode}")
        out = str(tmp_path / f"m_{mode}.json")
        engine = SimEngine(CFG, build_optimizer(ocfg, params, CFG, num_stages=1))
        state = engine.init_state(params=params)
        _, losses = run_loop(
            engine, batches(CFG, 4, 16, seed=0),
            LoopConfig(steps=steps, log_every=2, ckpt_dir=ckpt, ckpt_every=2,
                       out_path=out, async_io=mode),
            state=state,
        )
        from repro.checkpoint import load_checkpoint

        tree, step, _ = load_checkpoint(ckpt)
        outs[mode] = (losses, json.loads(open(out).read()), step,
                      jax.tree_util.tree_leaves(tree))
    assert outs[True][0] == outs[False][0]
    assert outs[True][1] == outs[False][1]
    assert outs[True][2] == outs[False][2] == steps
    for a, b in zip(outs[True][3], outs[False][3]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_writer_failure_raises_on_loop_thread(tmp_path):
    """A failed background write must fail the run, not vanish into the
    daemon thread: point the metrics file at an unwritable path."""
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("file where a directory must go")
    params = init_model(jax.random.PRNGKey(0), CFG)
    ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=3)
    engine = SimEngine(CFG, build_optimizer(ocfg, params, CFG, num_stages=1))
    state = engine.init_state(params=params)
    with pytest.raises(OSError):
        run_loop(
            engine, batches(CFG, 4, 16, seed=0),
            LoopConfig(steps=3, log_every=1,
                       out_path=str(blocker / "m.json"), async_io=True),
            state=state,
        )


SYNC_AGREEMENT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, json
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec, OptimizerConfig
from repro.data import batches
from repro.engine import LoopConfig, SimEngine, SpmdEngine, run_loop
from repro.launch.mesh import make_mesh_compat
from repro.models import init_model
from repro.optim.factory import build_optimizer

cfg = ModelConfig(num_layers=4, d_model=32, d_ff=64, vocab_size=64, max_seq_len=64,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
                  pattern=(BlockSpec("attn","dense"),), scan_layers=False)
K, M, steps = 4, 4, 8
params = init_model(jax.random.PRNGKey(0), cfg)
ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=steps,
                       schedule="constant")

sim = SimEngine(cfg, build_optimizer(ocfg, params, cfg, num_stages=1))
s_state = sim.init_state(params=params)
_, sim_losses = run_loop(sim, batches(cfg, M * 2, 16, seed=0),
                         LoopConfig(steps=steps), state=s_state)

mesh = make_mesh_compat((K, 1), ("stage", "data"))
res = {"sim": sim_losses}
for sched in ("fill_drain", "1f1b"):
    for async_grads in (False, True):
        eng = SpmdEngine(cfg, ocfg, num_stages=K, num_microbatches=M, mesh=mesh,
                         async_grads=async_grads, schedule=sched)
        st = eng.init_state(params=params)
        _, losses = run_loop(eng, batches(cfg, M * 2, 16, seed=0),
                             LoopConfig(steps=steps), state=st)
        res[("async_" if async_grads else "sync_") + sched] = losses
print(json.dumps(res))
"""


def test_sim_and_spmd_schedules_agree():
    """With the delay FIFO disabled, the SPMD pipeline step — under either
    tick schedule — is the same optimisation problem as the 1-stage
    simulation: loss curves must agree within fp32 tolerance. With the FIFO
    enabled, both schedules feed it the same synchronous gradient, so their
    async curves must agree with each other too."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SYNC_AGREEMENT_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), env=env, timeout=1800,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])

    def maxdiff(a, b):
        return max(abs(x - y) for x, y in zip(res[a], res[b]))

    assert maxdiff("sim", "sync_fill_drain") < 2e-3, res
    assert maxdiff("sim", "sync_1f1b") < 2e-3, res
    assert maxdiff("async_fill_drain", "async_1f1b") < 2e-3, res
    # staleness must actually bite: the async curve differs from sync
    assert maxdiff("sync_1f1b", "async_1f1b") > 1e-4, res


STAGE_AWARE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, json
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec, OptimizerConfig
from repro.data import batches
from repro.engine import LoopConfig, SimEngine, SpmdEngine, run_loop
from repro.launch.mesh import make_mesh_compat
from repro.models import init_model
from repro.optim.factory import build_optimizer

cfg = ModelConfig(num_layers=4, d_model=32, d_ff=64, vocab_size=64, max_seq_len=64,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
                  pattern=(BlockSpec("attn","dense"),), scan_layers=False)
K, M, steps = 4, 4, 8
params = init_model(jax.random.PRNGKey(0), cfg)
mesh = make_mesh_compat((K, 1), ("stage", "data"))
res = {}

# stage-aware basis rotation, synchronous: the vectorized per-stage refresh
# on the stacked layout vs the per-leaf scalar path on the sim layout
ocfg = OptimizerConfig(name="basis_rotation", learning_rate=3e-3, total_steps=steps,
                       rotation_freq=5, stage_aware=True, schedule="constant")
sim = SimEngine(cfg, build_optimizer(ocfg, params, cfg, num_stages=K,
                                     apply_delay=False))
st = sim.init_state(params=params)
_, res["sim_sync"] = run_loop(sim, batches(cfg, M * 2, 16, seed=0),
                              LoopConfig(steps=steps), state=st)
eng = SpmdEngine(cfg, ocfg, num_stages=K, num_microbatches=M, mesh=mesh,
                 async_grads=False)
st = eng.init_state(params=params)
_, res["spmd_sync"] = run_loop(eng, batches(cfg, M * 2, 16, seed=0),
                               LoopConfig(steps=steps), state=st)

# the delay-aware baselines now run natively on the stacked layout
for name in ("pipedream_lr", "delay_compensation"):
    o = OptimizerConfig(name=name, learning_rate=3e-3, total_steps=steps,
                        schedule="constant")
    eng = SpmdEngine(cfg, o, num_stages=K, num_microbatches=M, mesh=mesh)
    st = eng.init_state(params=params)
    _, res[name] = run_loop(eng, batches(cfg, M * 2, 16, seed=0),
                            LoopConfig(steps=steps), state=st)

# kernel path (interpret-mode Pallas inside the jitted spmd step)
ok = OptimizerConfig(name="basis_rotation", learning_rate=3e-3, total_steps=4,
                     rotation_freq=5, stage_aware=True, schedule="constant")
eng = SpmdEngine(cfg, ok, num_stages=K, num_microbatches=M, mesh=mesh,
                 async_grads=False, use_kernels=True)
st = eng.init_state(params=params)
_, res["spmd_kernels"] = run_loop(eng, batches(cfg, M * 2, 16, seed=0),
                                  LoopConfig(steps=4), state=st)
print(json.dumps(res))
"""


def test_spmd_stage_aware_and_delay_aware_bases():
    """The SPMD backend hosts everything the sim hosts: stage-aware rotation
    frequencies agree with the sim backend under synchronous gradients (the
    vectorized per-stage mask == the per-leaf scalar refresh, up to fp32
    noise amplified through the QR refresh), the delay-aware baselines run
    natively on the stacked layout, and the Pallas kernel path reproduces
    the XLA path."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", STAGE_AWARE_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), env=env, timeout=1800,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])

    diffs = [abs(a - b) for a, b in zip(res["sim_sync"], res["spmd_sync"])]
    # wiring errors show up immediately; QR-refresh chaos grows slowly
    assert max(diffs[:2]) < 2e-3, res
    assert max(diffs) < 5e-2, res
    # kernel path tracks the XLA path on the same problem
    kdiff = [abs(a - b) for a, b in zip(res["spmd_kernels"], res["spmd_sync"])]
    assert max(kdiff) < 5e-2, res
    for name in ("pipedream_lr", "delay_compensation"):
        ls = res[name]
        assert all(abs(x) < 1e9 for x in ls), (name, ls)
        assert ls[-1] < ls[0], (name, ls)  # actually optimises


MULTI_POD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import json
import numpy as np
import jax
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec, OptimizerConfig
from repro.checkpoint import load_checkpoint
from repro.data import batches, host_assembled_batches
from repro.engine import LoopConfig, SimEngine, SpmdEngine, run_loop
from repro.engine.loop import resume_if_present
from repro.launch.topology import Topology
from repro.models import init_model
from repro.optim.factory import build_optimizer

cfg = ModelConfig(num_layers=4, d_model=32, d_ff=64, vocab_size=64, max_seq_len=64,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
                  pattern=(BlockSpec("attn","dense"),), scan_layers=False)
K, M, steps = 2, 2, 8
params = init_model(jax.random.PRNGKey(0), cfg)
ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=steps,
                       schedule="constant")
# both topologies split the global batch into TWO data shards, so every
# reduction is a two-term sum — bitwise identical regardless of pod layout
topoA = Topology(stages=K, data=2)           # single-pod (2, 2)
topoB = Topology(stages=K, data=1, pods=2)   # two-pod (2, 2, 1)

def make(topo):
    return SpmdEngine(cfg, ocfg, num_stages=K, num_microbatches=M,
                      async_grads=False, topology=topo)

def dataA():
    return batches(cfg, 8, 16, seed=0)

def dataB():  # the host-sharded loading path, one emulated host per pod
    return host_assembled_batches(cfg, 8, 16, 2, seed=0)

res = {}
eng = make(topoA)
st = eng.init_state(params=params)
_, res["la"] = run_loop(eng, dataA(), LoopConfig(steps=steps), state=st)
eng = make(topoB)
st = eng.init_state(params=params)
_, res["lb"] = run_loop(eng, dataB(), LoopConfig(steps=steps), state=st)

sim = SimEngine(cfg, build_optimizer(ocfg, params, cfg, num_stages=1))
st = sim.init_state(params=params)
_, res["sim"] = run_loop(sim, dataA(), LoopConfig(steps=steps), state=st)

# sharded checkpoint mid-run on topology B (one arrays file per stage shard)
ckpt = sys.argv[1]
engB = make(topoB)
stB = engB.init_state(params=params)
stB, res["first4"] = run_loop(engB, dataB(), LoopConfig(steps=4, ckpt_dir=ckpt,
                                                        ckpt_every=4), state=stB)
manifest = json.load(open(os.path.join(ckpt, "manifest.json")))
res["manifest"] = {"format": manifest.get("format"),
                   "num_shards": manifest.get("num_shards"),
                   "sharded_leaves": sum(a is not None
                                         for a in manifest.get("shard_axes", [])),
                   "meta": manifest.get("meta")}
# round-trip: the reassembled tree equals the live (gathered) state exactly
tree, step, _ = load_checkpoint(ckpt)
ref = engB.checkpoint_tree(stB)
res["roundtrip_exact"] = bool(all(
    np.array_equal(np.asarray(a), np.asarray(b)) and
    np.asarray(a).dtype == np.asarray(b).dtype
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(tree))))

# resume on the SAME topology: sharded iterator fast-forwards in lock-step
engB2 = make(topoB)
stB2 = engB2.init_state(params=params)
db = dataB()
stB2, start = resume_if_present(engB2, stB2, ckpt, db)
res["start"] = start
_, res["restB"] = run_loop(engB2, db, LoopConfig(steps=steps), state=stB2,
                           start_step=start)

# resume on a DIFFERENT topology: load reassembles, the new mesh re-shards
engA2 = make(topoA)
stA2 = engA2.init_state(params=params)
da = dataA()
stA2, start = resume_if_present(engA2, stA2, ckpt, da)
_, res["restA"] = run_loop(engA2, da, LoopConfig(steps=steps), state=stA2,
                           start_step=start)
print(json.dumps(res))
"""


def test_multi_pod_topology_bitwise_and_sharded_checkpoint(tmp_path):
    """The pod axis must be invisible to the math: a 2-pod (pod, stage, data)
    run — gradients all-reduced over ("pod", "data"), data loaded through
    the host-sharded iterators — produces bit-identical losses to the
    single-pod run with the same data-shard count, and stays within fp32
    tolerance of the sim backend. A sharded checkpoint saved mid-run (one
    arrays file per stage shard, no gather) resumes bit-identically on the
    same topology AND when reloaded under the other topology."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    ckpt = str(tmp_path / "ckpt")
    out = subprocess.run(
        [sys.executable, "-c", MULTI_POD_SCRIPT, ckpt],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), env=env, timeout=1800,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])

    # 2-pod == 1-pod, bit for bit
    assert res["lb"] == res["la"], res
    # and within fp32 tolerance of the simulator (different op order)
    assert max(abs(a - b) for a, b in zip(res["sim"], res["la"])) < 2e-3, res

    # sharded on-disk format actually sharded
    m = res["manifest"]
    assert m["format"] == "sharded" and m["num_shards"] == 2, m
    assert m["sharded_leaves"] > 0, m
    assert m["meta"]["topology"] == "2x2x1", m
    assert res["roundtrip_exact"], res

    # resume == uninterrupted, bitwise, on both topologies
    assert res["start"] == 4
    assert res["first4"] + res["restB"] == res["lb"], res
    assert res["first4"] + res["restA"] == res["la"], res


SCHEDULE_MEMORY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, json
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec
from repro.engine import make_pipeline_grad, stack_stage_params
from repro.engine.schedules import SCHEDULE_INVARIANTS
from repro.launch.mesh import make_mesh_compat
from repro.models import init_model
from repro.analysis import (check_no_dot_outside_cond,
                            check_scan_body_constant_in_microbatches,
                            check_stash_bound, max_float_bytes)

# vocab distinct from d_model/d_ff so vocab-sized dots are unambiguous
cfg = ModelConfig(num_layers=4, d_model=32, d_ff=64, vocab_size=96, max_seq_len=64,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
                  pattern=(BlockSpec("attn","dense"),), scan_layers=False)
K = 4
V = cfg.vocab_size
params = init_model(jax.random.PRNGKey(0), cfg)
stacked, shared = stack_stage_params(params, cfg, K)
mesh = make_mesh_compat((K, 1), ("stage", "data"))

def trace(sched, m):
    gf = make_pipeline_grad(cfg, mesh, K, m, schedule=sched)
    b = {"tokens": jnp.zeros((m, 2, 16), jnp.int32),
         "labels": jnp.zeros((m, 2, 16), jnp.int32)}
    return jax.make_jaxpr(gf)(stacked, shared, b)

jxs = {s: {m: trace(s, m) for m in (4, 16)} for s in ("fill_drain", "1f1b")}
res = {}
for sched, by_m in jxs.items():
    inv = SCHEDULE_INVARIANTS[sched]
    res[sched] = {
        "const": check_scan_body_constant_in_microbatches(
            by_m, expect_const_bytes=inv["const_float_bytes_in_M"]).to_json(),
        "vocab": check_no_dot_outside_cond(
            by_m[4], V, require_gated=inv["vocab_dot_gated"]).to_json(),
        "maxf_m4": max_float_bytes(by_m[4]),
    }
res["1f1b"]["stash"] = check_stash_bound(
    jxs["1f1b"][4], K, (2, 16, cfg.d_model)).to_json()
print(json.dumps(res))
"""


def test_1f1b_jaxpr_and_activation_buffer_constant_in_microbatches():
    """The 1F1B schedule keeps BOTH the traced program and the largest live
    float buffer constant in the microbatch count M: the scanned tick body is
    traced once (O(1) jaxpr), and the explicit-backward stash holds 2K-1
    activations (O(K)), never an O(M) output/residual buffer. Fill-drain's
    buffer must grow with M — that contrast proves the measurement sees the
    schedule memory, not an artifact. All measurements run through the named
    checks in `repro.analysis` (the single shared jaxpr walker): each
    schedule is audited against its `SCHEDULE_INVARIANTS` declaration."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCHEDULE_MEMORY_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # O(1) trace in M for both schedules; O(K) float buffers for 1F1B,
    # strictly-growing collect/residual buffers for fill-drain — the
    # expect_const_bytes branch of the check enforces the right one per the
    # schedule's declared invariants
    assert res["1f1b"]["const"]["passed"], res["1f1b"]["const"]
    assert res["fill_drain"]["const"]["passed"], res["fill_drain"]["const"]
    # at equal M the 1F1B live-float peak is strictly smaller
    assert res["1f1b"]["maxf_m4"] < res["fill_drain"]["maxf_m4"], res
    # the 1F1B tick body's O(vocab) LM-head matmul is gated behind lax.cond
    # (fill-drain is audited ungated-allowed per its declaration)
    assert res["1f1b"]["vocab"]["passed"], res["1f1b"]["vocab"]
    assert res["1f1b"]["vocab"]["data"]["inside_cond"] >= 1, res
    assert res["fill_drain"]["vocab"]["passed"], res["fill_drain"]["vocab"]
    # and the input stash never exceeds its 2K-1 slots
    assert res["1f1b"]["stash"]["passed"], res["1f1b"]["stash"]
    assert 2 * 4 - 1 in res["1f1b"]["stash"]["data"]["slot_counts"], res
