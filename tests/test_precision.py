"""The bf16_compute precision policy: the PrecisionPolicy layer itself, the
engine's policy resolution, and — mirroring test_analysis's seeded mutations —
the BF16_COMPUTE_POLICY analyzer check against the REAL kernel-backed bf16
step jaxpr: it must pass on the healthy trace and FAIL (non-vacuously) when
the compute cast is deleted or bf16 leaks into optimizer state."""
import json
import os
import subprocess
import sys

from repro.configs.base import (
    PRECISION_POLICIES,
    ModelConfig,
    PrecisionPolicy,
)

# ---------------------------------------------------------------------------
# the policy layer (pure config rewriting, no jax)
# ---------------------------------------------------------------------------


def test_precision_policy_registry_and_apply():
    assert set(PRECISION_POLICIES) == {"f32", "bf16"}
    f32, bf16 = PRECISION_POLICIES["f32"], PRECISION_POLICIES["bf16"]
    assert f32.name == "f32" and bf16.name == "bf16_compute"

    cfg = ModelConfig()
    out = bf16.apply(cfg)
    # bf16 activations/matmuls, f32 masters, f32 vocab head — the contract
    # BF16_COMPUTE_POLICY enforces on the traced step
    assert out.dtype == "bfloat16"
    assert out.param_dtype == "float32"
    assert out.logits_fp32 is True
    # f32 policy is the identity on a default config
    back = f32.apply(out)
    assert back.dtype == "float32" and back.param_dtype == "float32"


def test_precision_policy_is_declarative():
    # the policy only selects dtypes; it must not touch unrelated knobs
    cfg = ModelConfig(num_layers=7, d_model=40, use_kernels=True)
    out = PRECISION_POLICIES["bf16"].apply(cfg)
    assert (out.num_layers, out.d_model, out.use_kernels) == (7, 40, True)
    custom = PrecisionPolicy(name="x", dtype="bfloat16",
                             param_dtype="bfloat16", logits_fp32=False)
    out = custom.apply(cfg)
    assert out.param_dtype == "bfloat16" and out.logits_fp32 is False


# ---------------------------------------------------------------------------
# engine resolution + analyzer mutations on the real bf16 kernel step
# (subprocess: needs a 2-device stage mesh)
# ---------------------------------------------------------------------------

BF16_MUTATION_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import json, tempfile
import jax, jax.numpy as jnp
from repro.configs.base import (ModelConfig, AttentionConfig, BlockSpec,
                                OptimizerConfig, PRECISION_POLICIES)
from repro.engine.spmd import SpmdEngine, stack_stage_params
import repro.engine.schedules as schedules
from repro.launch.topology import Topology
from repro.models import init_model
from repro.analysis import (BF16_COMPUTE_POLICY, check_dtype_policy,
                            check_no_dot_outside_cond, check_pallas_in_scan,
                            check_stash_bound)

cfg = ModelConfig(num_layers=2, d_model=16, d_ff=24, vocab_size=96,
                  max_seq_len=32,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=8),
                  pattern=(BlockSpec("attn","dense"),), scan_layers=False)
cfg = PRECISION_POLICIES["bf16"].apply(cfg).replace(use_kernels=True)
K, M, S, V = 2, 2, 8, 96
topo = Topology(stages=K, data=1)
mesh = topo.make_mesh()
shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
stacked_s, shared_s = jax.eval_shape(lambda p: stack_stage_params(p, cfg, K), shapes)

def jaxpr_for(schedule, model_cfg=None, stacked=None, shared=None):
    gf = schedules.make_schedule_grad(model_cfg if model_cfg is not None
                                      else cfg, mesh, K, M, schedule=schedule)
    tok = jax.ShapeDtypeStruct((M, 1, S), jnp.int32)
    return jax.make_jaxpr(gf)(stacked if stacked is not None else stacked_s,
                              shared if shared is not None else shared_s,
                              {"tokens": tok, "labels": tok})

def run_checks(jx, schedule):
    # fill-drain computes the head after the drain (no in-scan vocab dot to
    # gate), so the gating requirement applies to 1f1b only — same contract
    # as SCHEDULE_INVARIANTS in the matrix runner
    out = {
        "dtype": check_dtype_policy(jx, BF16_COMPUTE_POLICY).to_json(),
        "kernels": check_pallas_in_scan(jx, min_calls=3).to_json(),
        "vocab": check_no_dot_outside_cond(
            jx, V, require_gated=(schedule == "1f1b")).to_json(),
    }
    if schedule == "1f1b":
        out["stash"] = check_stash_bound(jx, K, (1, S, cfg.d_model)).to_json()
    return out

res = {"baseline_" + s: run_checks(jaxpr_for(s), s)
       for s in ("fill_drain", "1f1b")}

# mutation A: the precision policy was never applied — the run claims bf16
# but the traced step computes purely in f32, so no bf16 op remains anywhere
# and require_present=("bfloat16",) must flag the policy as vacuous
res["no_cast"] = run_checks(
    jaxpr_for("fill_drain", model_cfg=cfg.replace(dtype="float32")),
    "fill_drain")

# mutation B: bf16 leaks into the parameter masters / optimizer state
bf16_leaf = lambda a: jax.ShapeDtypeStruct(
    a.shape, jnp.bfloat16 if a.dtype == jnp.float32 else a.dtype)
res["bf16_state"] = run_checks(
    jaxpr_for("fill_drain", stacked=jax.tree.map(bf16_leaf, stacked_s),
              shared=jax.tree.map(bf16_leaf, shared_s)),
    "fill_drain")

# engine-level resolution: the same policy by name, surfaced in ckpt meta
engine = SpmdEngine(cfg.replace(dtype="float32"),
                    OptimizerConfig(name="adam", learning_rate=1e-3,
                                    total_steps=4, schedule="constant"),
                    num_stages=K, num_microbatches=M, async_grads=False,
                    topology=topo, use_kernels=True, precision="bf16")
state = engine.init_state(key=jax.random.PRNGKey(0))
with tempfile.TemporaryDirectory() as d:
    engine.save_checkpoint(d, state, step=0)
    meta = json.load(open(os.path.join(d, "manifest.json")))["meta"]
res["engine"] = {
    "precision": engine.precision,
    "cfg_dtype": engine.cfg.dtype,
    "cfg_param_dtype": engine.cfg.param_dtype,
    "meta_precision": meta.get("precision"),
}
print(json.dumps(res))
"""


def test_bf16_policy_mutations_flip_exactly_the_dtype_check():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", BF16_MUTATION_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])

    # healthy bf16 kernel step: every check green on BOTH schedules, with the
    # flash fwd+bwd pallas_calls actually inside the scanned tick body
    for sched in ("fill_drain", "1f1b"):
        base = res["baseline_" + sched]
        for name, check in base.items():
            assert check["passed"], (sched, name, check)
        assert base["kernels"]["data"]["in_scan"] >= 3, base["kernels"]

    # deleting the compute cast flips ONLY the dtype check (vacuity clause)
    mut = res["no_cast"]
    assert not mut["dtype"]["passed"], mut
    assert "nowhere" in mut["dtype"]["detail"], mut["dtype"]
    assert mut["kernels"]["passed"] and mut["vocab"]["passed"], mut

    # bf16 optimizer-state/master leaves flip ONLY the dtype check
    # (state-dtype clause), not the structural ones
    mut = res["bf16_state"]
    assert not mut["dtype"]["passed"], mut
    assert "state dtype" in mut["dtype"]["detail"], mut["dtype"]
    assert mut["kernels"]["passed"] and mut["vocab"]["passed"], mut

    # engine resolves the string policy and stamps it into checkpoint meta
    eng = res["engine"]
    assert eng["precision"] == "bf16_compute", eng
    assert eng["cfg_dtype"] == "bfloat16", eng
    assert eng["cfg_param_dtype"] == "float32", eng
    assert eng["meta_precision"] == "bf16_compute", eng
