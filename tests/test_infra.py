"""Data pipeline, checkpointing, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import (
    load_checkpoint,
    save_checkpoint,
    save_sharded_checkpoint,
)
from repro.launch.topology import Topology
from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    MoEConfig,
    ModelConfig,
    OptimizerConfig,
)
from repro.data import SyntheticLM, batches
from repro.models import init_model
from repro.optim.factory import build_optimizer
from repro.sharding.rules import (
    generic_activation_pspec,
    opt_state_pspecs,
    param_pspec,
    params_pspecs,
    tokens_pspec,
)

MESH = {"data": 16, "model": 16}


def test_data_deterministic_and_learnable():
    cfg = ModelConfig(vocab_size=64)
    b1 = next(batches(cfg, 4, 32, seed=3))
    b2 = next(batches(cfg, 4, 32, seed=3))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert b1["labels"].shape == (4, 32)
    # labels are next-token shifted
    stream = SyntheticLM(64, seed=0)
    toks = stream.sample(2, 16)
    assert toks.shape == (2, 17)
    # planted Markov structure: transition entropy < unigram entropy
    table = stream.table
    p = table.mean(axis=0)
    h_uni = -(p * np.log(p + 1e-12)).sum()
    h_cond = -(table * np.log(table + 1e-12)).sum(axis=1).mean()
    assert h_cond < h_uni - 0.1  # there is something to learn


def test_sampler_rounding_edge_clamps_to_last_token():
    """Regression: when float rounding leaves u >= cum[-1], the old
    `(u < cum).argmax` draw returned token 0 (argmax of all-False); the
    clamped searchsorted draw must land at the tail of the distribution."""
    stream = SyntheticLM(32, seed=0)

    class EdgeRng:
        """rand() returns 1.0 — beyond every row's cumsum — to force the edge."""

        def __init__(self, inner):
            self.inner = inner

        def rand(self, *shape):
            return np.ones(shape)

        def randint(self, *a, **k):
            return self.inner.randint(*a, **k)

    stream.rng = EdgeRng(stream.rng)
    toks = stream.sample(4, 8)
    assert (toks >= 0).all() and (toks < 32).all()
    # every draw hit the u >= cum[-1] edge: must clamp to the tail, never
    # fall back to token 0 (the most-probable Zipf head — a silent bias)
    assert (toks[:, 1:] != 0).all()
    assert (toks[:, 1:] >= 30).all()


def test_sampler_off_edge_draw_unchanged():
    """The searchsorted draw is the first index with cum > u — identical to
    the previous strict-inequality argmax away from the rounding edge, so
    fixed-seed token streams are preserved."""
    stream = SyntheticLM(64, seed=5)
    toks = stream.sample(8, 32)
    ref = SyntheticLM(64, seed=5)
    out = np.empty_like(toks)
    out[:, 0] = ref.rng.randint(0, 64, size=8)
    for t in range(32):
        cum = np.cumsum(ref._rows(out[:, t]), axis=1)
        u = ref.rng.rand(8, 1)
        old = (u < cum).argmax(axis=1)  # the pre-fix formula
        valid = (u < cum[:, -1:]).ravel()  # rows where it was well-defined
        new_draw = np.minimum((cum <= u).sum(axis=1), 63)
        np.testing.assert_array_equal(new_draw[valid], old[valid])
        out[:, t + 1] = new_draw
    np.testing.assert_array_equal(toks, out)


def test_sampler_golden_stream_and_table_dtype():
    """Regression for the f64 leak fix: the exposed transition `table` is now
    float32 (no f64 arrays cross into jit'd code), but the SAMPLING path
    still draws through the implicit-f64 numpy pipeline, so fixed-seed token
    streams are bit-identical to the pre-fix values captured below."""
    stream = SyntheticLM(64, seed=5)
    assert stream.table.dtype == np.float32
    golden = np.array([
        [10, 0, 9, 47, 39, 51, 62, 44, 55, 18, 46, 46, 46],
        [9, 60, 0, 9, 6, 22, 28, 25, 60, 10, 0, 37, 23],
    ])
    np.testing.assert_array_equal(stream.sample(2, 12), golden)


def test_sharded_batches_partition_global_stream():
    """Regression: host shards must be slices of the SAME seeded global
    stream — concatenating them reproduces `batches(...)` bit-for-bit at
    every step (the old ``seed * num_hosts + host_id`` scheme gave hosts
    unrelated streams that partitioned nothing)."""
    from repro.data import host_assembled_batches, sharded_batches

    cfg = ModelConfig(vocab_size=64)
    ref = batches(cfg, 8, 16, seed=3)
    its = [sharded_batches(cfg, 8, 16, 4, h, seed=3) for h in range(4)]
    asm = host_assembled_batches(cfg, 8, 16, 4, seed=3)
    for _ in range(3):
        want = next(ref)
        shards = [next(it) for it in its]
        for key in ("tokens", "labels"):
            assert all(s[key].shape == (2, 16) for s in shards)
            cat = np.concatenate([np.asarray(s[key]) for s in shards], axis=0)
            np.testing.assert_array_equal(cat, np.asarray(want[key]))
        got = next(asm)
        for key in ("tokens", "labels"):
            np.testing.assert_array_equal(
                np.asarray(got[key]), np.asarray(want[key])
            )


def test_sharded_batches_single_host_stream_unchanged():
    """num_hosts=1 must reproduce the historical `batches(seed)` stream, so
    existing fixed-seed runs are untouched by the partition fix."""
    from repro.data import sharded_batches

    cfg = ModelConfig(vocab_size=64)
    a = sharded_batches(cfg, 8, 16, 1, 0, seed=5)
    b = batches(cfg, 8, 16, seed=5)
    for _ in range(2):
        x, y = next(a), next(b)
        np.testing.assert_array_equal(np.asarray(x["tokens"]), np.asarray(y["tokens"]))
        np.testing.assert_array_equal(np.asarray(x["labels"]), np.asarray(y["labels"]))


@pytest.mark.parametrize("num_hosts", [1, 2, 4, 8])
def test_sharded_batches_any_divisor_split_partitions_stream(num_hosts):
    """Property (deterministic sweep; the hypothesis version lives in
    test_data_properties.py): for EVERY host count dividing the batch,
    (a) per-host slices concatenate bit-for-bit to the global `batches()`
    stream at every step, and (b) a fresh host iterator fast-forwarded k
    steps — the resume path — matches the uninterrupted host stream."""
    from repro.data import sharded_batches

    cfg = ModelConfig(vocab_size=64)
    B, S, steps = 8, 16, 3
    ref = batches(cfg, B, S, seed=11)
    its = [sharded_batches(cfg, B, S, num_hosts, h, seed=11)
           for h in range(num_hosts)]
    stream = [[next(it) for it in its] for _ in range(steps)]
    for t in range(steps):
        want = next(ref)
        for key in want:
            cat = np.concatenate(
                [np.asarray(s[key]) for s in stream[t]], axis=0
            )
            np.testing.assert_array_equal(cat, np.asarray(want[key]))
    for h in (0, num_hosts - 1):
        for k in (0, steps - 1):
            fresh = sharded_batches(cfg, B, S, num_hosts, h, seed=11)
            for _ in range(k):
                next(fresh)
            got = next(fresh)
            for key in got:
                np.testing.assert_array_equal(
                    np.asarray(got[key]), np.asarray(stream[k][h][key])
                )


@pytest.mark.parametrize("data_shards,splits", [
    (1, [(0, 1)]),
    (2, [(0, 1), (1, 2)]),
    (2, [(0, 2)]),
    (4, [(0, 2), (2, 4)]),
    (4, [(0, 1), (1, 2), (2, 3), (3, 4)]),
])
def test_process_local_batches_partition_microbatched_stream(
    data_shards, splits
):
    """The multi-controller loader must reproduce the global MICROBATCHED
    array: stacking each process's `[lo, hi)` row-shard slice along the
    shard axis equals ``batches()`` reshaped (M, shards, w, S) — the
    invariant that makes loss curves independent of process count and lets
    elastic resumes continue the identical stream."""
    from repro.data import process_local_batches

    cfg = ModelConfig(vocab_size=64)
    B, S, M = 8, 16, 2
    w = B // M // data_shards
    ref = batches(cfg, B, S, seed=4)
    its = [
        process_local_batches(cfg, B, S, num_microbatches=M,
                              data_shards=data_shards, shard_lo=lo,
                              shard_hi=hi, seed=4)
        for lo, hi in splits
    ]
    for _ in range(3):
        want = next(ref)
        parts = [next(it) for it in its]
        for key in want:
            glob = np.asarray(want[key]).reshape(M, data_shards, w, -1)
            got = np.concatenate(
                [np.asarray(p[key]).reshape(M, hi - lo, w, -1)
                 for p, (lo, hi) in zip(parts, splits)],
                axis=1,
            )
            np.testing.assert_array_equal(got, glob)


def test_data_modalities():
    audio = ModelConfig(vocab_size=32, num_codebooks=4)
    b = next(batches(audio, 2, 8))
    assert b["tokens"].shape == (2, 8, 4)
    vlm = ModelConfig(vocab_size=32, frontend="vision", frontend_tokens=3, frontend_dim=16)
    b = next(batches(vlm, 2, 8))
    assert b["frontend"].shape == (2, 3, 16)


def test_checkpoint_roundtrip(tmp_path):
    cfg = ModelConfig(
        num_layers=2, d_model=32, d_ff=64, vocab_size=64,
        attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = build_optimizer(
        OptimizerConfig(name="basis_rotation", total_steps=10), params, cfg, num_stages=2
    )
    state = opt.init(params)
    g = jax.tree.map(jnp.ones_like, params)
    _, state = opt.update(g, state, params, jnp.int32(0))
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, (params, state), step=7, meta={"note": "t"})
    (p2, s2), step, meta = load_checkpoint(path)
    assert step == 7 and meta["note"] == "t"
    assert jax.tree.structure((params, state)) == jax.tree.structure((p2, s2))
    for a, b in zip(jax.tree.leaves((params, state)), jax.tree.leaves((p2, s2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_interrupted_save_keeps_previous(tmp_path, monkeypatch):
    """A crash mid-save must not corrupt the only checkpoint: files are
    written to temp names and atomically swapped in (arrays first, manifest
    last), so the pre-crash checkpoint stays loadable."""
    path = str(tmp_path / "ckpt")
    tree1 = {"w": jnp.arange(4.0), "b": jnp.ones((2, 2))}
    save_checkpoint(path, tree1, step=1, meta={"note": "good"})

    # crash while writing the arrays file (partial bytes on disk, then die)
    def savez_boom(file, **kw):
        with open(file, "wb") as f:
            f.write(b"\x00partial-garbage")
        raise RuntimeError("simulated crash during array write")

    monkeypatch.setattr(np, "savez", savez_boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_checkpoint(path, {"w": jnp.arange(4.0) + 9, "b": jnp.zeros((2, 2))},
                        step=2)
    monkeypatch.undo()

    tree, step, meta = load_checkpoint(path)
    assert step == 1 and meta["note"] == "good"
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(4.0))

    # crash while serialising the manifest: same guarantee
    import json as _json

    real_dump = _json.dump

    def dump_boom(obj, f, **kw):
        f.write('{"spec": "trunc')
        raise RuntimeError("simulated crash during manifest write")

    monkeypatch.setattr(_json, "dump", dump_boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_checkpoint(path, tree1, step=3)
    monkeypatch.setattr(_json, "dump", real_dump)

    tree, step, _ = load_checkpoint(path)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["b"]), np.ones((2, 2)))

    # crash after the arrays file commits but before the manifest swap: the
    # old manifest still names the old arrays file — never a mixed state
    real_replace = os.replace

    def replace_boom(src, dst):
        if dst.endswith("manifest.json"):
            raise RuntimeError("simulated crash before manifest commit")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", replace_boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_checkpoint(path, {"w": jnp.arange(4.0) + 9, "b": jnp.zeros((2, 2))},
                        step=4)
    monkeypatch.setattr(os, "replace", real_replace)

    tree, step, _ = load_checkpoint(path)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(4.0))


def test_topology_shapes_and_axes():
    t = Topology.single_host(4)
    assert t.shape == (4, 1) and t.axis_names == ("stage", "data")
    assert t.schedule_data_axis == "data" and t.data_shards == 1
    p = Topology.single_pod()
    assert p.shape == (16, 16) and p.num_devices == 256
    m = Topology.multi_pod()
    assert m.shape == (2, 16, 16) and m.axis_names == ("pod", "stage", "data")
    assert m.schedule_data_axis == ("pod", "data")
    assert m.data_shards == 32 and m.num_devices == 512
    assert m.describe() == "2x16x16"
    assert m.stage_spec(3) == P("stage", None, None)
    assert m.batch_spec() == P(None, ("pod", "data"), None)
    assert Topology.from_device_count(4, pods=2, data=0, device_count=16) == \
        Topology(stages=4, data=2, pods=2)
    with pytest.raises(ValueError):
        Topology.from_device_count(3, device_count=16)
    with pytest.raises(ValueError):
        Topology(stages=0)


def test_topology_mesh_roundtrip():
    # single device: the smoke (stage=1, data=1) mesh carries the axis names
    t = Topology.single_host(1)
    mesh = t.make_mesh()
    assert Topology.from_mesh(mesh) == t


def test_sharded_checkpoint_roundtrip_equals_gathered(tmp_path):
    """One arrays file per stage shard must reassemble to exactly the tree a
    gathered save stores — values, dtypes and structure."""
    tree = (
        {"stacked": jnp.arange(24.0).reshape(4, 3, 2),
         "fifo": jnp.arange(24.0).reshape(3, 4, 2) * 2.0},
        {"shared": jnp.ones((5,), jnp.float32), "count": jnp.int32(7)},
    )
    # tree_flatten order: fifo, stacked, count, shared
    axes = [1, 0, None, None]
    sharded = str(tmp_path / "sharded")
    gathered = str(tmp_path / "gathered")
    save_sharded_checkpoint(sharded, tree, num_shards=4, step=9,
                            shard_axes=axes, meta={"topology": "4x1"})
    save_checkpoint(gathered, tree, step=9)
    names = sorted(os.listdir(sharded))
    assert sum(n.endswith(".npz") for n in names) == 4  # one file per shard
    a, step_a, meta_a = load_checkpoint(sharded)
    b, step_b, _ = load_checkpoint(gathered)
    assert step_a == step_b == 9 and meta_a["topology"] == "4x1"
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert np.asarray(x).dtype == np.asarray(y).dtype


def test_sharded_checkpoint_rejects_bad_axis(tmp_path):
    tree = {"w": jnp.zeros((3, 2))}
    with pytest.raises(ValueError, match="not divisible"):
        save_sharded_checkpoint(str(tmp_path), tree, num_shards=2,
                                shard_axes=[0])


def test_sharded_checkpoint_same_step_resave_never_overwrites(tmp_path, monkeypatch):
    """Re-saving the SAME step (re-run into an old dir, the loop's final-step
    double save) must not replace committed shard files in place: a crash
    mid-save would otherwise leave the old manifest naming a mixed
    old/new shard set. Fresh generation-suffixed names keep the previous
    checkpoint fully consistent until the new manifest commits."""
    path = str(tmp_path / "ckpt")
    axes = [0]
    save_sharded_checkpoint(path, {"w": jnp.zeros((2, 2))}, num_shards=2,
                            step=5, shard_axes=axes, meta={"run": "old"})
    old_files = {n for n in os.listdir(path) if n.endswith(".npz")}

    # crash after the first shard file of the re-save is committed
    real_savez = np.savez
    calls = {"n": 0}

    def savez_boom(file, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("simulated crash during shard write")
        return real_savez(file, **kw)

    monkeypatch.setattr(np, "savez", savez_boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_sharded_checkpoint(path, {"w": jnp.ones((2, 2))}, num_shards=2,
                                step=5, shard_axes=axes, meta={"run": "new"})
    monkeypatch.undo()

    # every old shard file is untouched and the old tree loads exactly
    assert old_files <= {n for n in os.listdir(path)}
    tree, step, meta = load_checkpoint(path)
    assert step == 5 and meta["run"] == "old"
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.zeros((2, 2)))

    # a successful re-save commits under fresh names and GCs the old set
    save_sharded_checkpoint(path, {"w": jnp.ones((2, 2))}, num_shards=2,
                            step=5, shard_axes=axes, meta={"run": "new"})
    tree, _, meta = load_checkpoint(path)
    assert meta["run"] == "new"
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.ones((2, 2)))
    assert not (old_files & {n for n in os.listdir(path)})


def test_sharded_checkpoint_interrupted_save_keeps_previous(tmp_path, monkeypatch):
    """The manifest swap is the single commit point for the whole shard file
    set: a crash while writing any shard file — or before the manifest
    lands — must leave the previous sharded checkpoint loadable."""
    path = str(tmp_path / "ckpt")
    tree1 = {"w": jnp.arange(8.0).reshape(4, 2), "b": jnp.ones((3,))}
    axes = [None, 0]  # flatten order: b, w
    save_sharded_checkpoint(path, tree1, num_shards=4, step=1, shard_axes=axes,
                            meta={"note": "good"})

    # crash while writing the third shard file
    real_savez = np.savez
    calls = {"n": 0}

    def savez_boom(file, **kw):
        calls["n"] += 1
        if calls["n"] == 3:
            with open(file, "wb") as f:
                f.write(b"\x00partial-garbage")
            raise RuntimeError("simulated crash during shard write")
        return real_savez(file, **kw)

    monkeypatch.setattr(np, "savez", savez_boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_sharded_checkpoint(path, {"w": jnp.zeros((4, 2)), "b": jnp.zeros((3,))},
                                num_shards=4, step=2, shard_axes=axes)
    monkeypatch.undo()

    tree, step, meta = load_checkpoint(path)
    assert step == 1 and meta["note"] == "good"
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.arange(8.0).reshape(4, 2))

    # crash before the manifest commit: all new shard files on disk, but the
    # old manifest still names the old (complete) set
    real_replace = os.replace

    def replace_boom(src, dst):
        if dst.endswith("manifest.json"):
            raise RuntimeError("simulated crash before manifest commit")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", replace_boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_sharded_checkpoint(path, {"w": jnp.zeros((4, 2)), "b": jnp.zeros((3,))},
                                num_shards=4, step=3, shard_axes=axes)
    monkeypatch.setattr(os, "replace", real_replace)

    tree, step, _ = load_checkpoint(path)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["b"]), np.ones((3,)))

    # a successful save GCs the stranded step-2/3 shard files
    save_sharded_checkpoint(path, tree1, num_shards=4, step=4, shard_axes=axes)
    left = sorted(n for n in os.listdir(path) if n.endswith(".npz"))
    assert left == [f"arrays-00000004-shard{s:05d}-of-00004.npz" for s in range(4)]


def test_param_pspec_rules():
    assert param_pspec("embed/embedding", (64000, 7168), MESH) == P("model", "data")
    assert param_pspec("lm_head", (7168, 64000), MESH) == P("data", "model")
    assert param_pspec("blocks/0/mixer/w_q", (60, 7168, 7168), MESH) == P(None, "data", "model")
    assert param_pspec("blocks/0/mixer/w_o", (7168, 7168), MESH) == P("model", "data")
    # expert parallel when experts divide the axis
    assert param_pspec("blocks/0/mlp/w_gate_e", (160, 5120, 1536), MESH) == P("model", "data", None)
    # hidden-dim fallback when they don't (mixtral: 8 experts, 16-way axis)
    assert param_pspec("blocks/0/mlp/w_gate_e", (8, 6144, 16384), MESH) == P(None, "data", "model")
    assert param_pspec("blocks/0/mlp/w_down_e", (8, 16384, 6144), MESH) == P(None, "model", "data")
    # non-divisible dims degrade to None, norms replicated
    assert param_pspec("blocks/0/mixer/w_q", (100, 50), MESH) == P(None, None)
    assert param_pspec("blocks/0/norm1/scale", (7168,), MESH) == P(None)


def test_opt_state_pspecs_structure():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),),
    )
    params = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    opt = build_optimizer(
        OptimizerConfig(name="basis_rotation", total_steps=10), params, cfg,
        num_stages=1, apply_delay=False,
    )
    st = jax.eval_shape(opt.init, params)
    specs = opt_state_pspecs(st, params, MESH)
    # every state leaf got a spec of matching rank
    flat_s = jax.tree.leaves(st)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for aval, spec in zip(flat_s, flat_p):
        assert isinstance(spec, P)
        assert len(spec) <= len(aval.shape)


def test_token_and_activation_specs():
    assert tokens_pspec(256, MESH) == P(("data",), None)
    assert tokens_pspec(7, MESH) == P(None, None)  # indivisible
    ms3 = {"pod": 2, "data": 16, "model": 16}
    assert tokens_pspec(256, ms3) == P(("pod", "data"), None)
    spec = generic_activation_pspec((128, 8, 32768, 128), MESH, batch_dim=0)
    assert spec[0] in ("data", ("data",)) and "model" in spec
