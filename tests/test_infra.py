"""Data pipeline, checkpointing, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    MoEConfig,
    ModelConfig,
    OptimizerConfig,
)
from repro.data import SyntheticLM, batches
from repro.models import init_model
from repro.optim.factory import build_optimizer
from repro.sharding.rules import (
    generic_activation_pspec,
    opt_state_pspecs,
    param_pspec,
    params_pspecs,
    tokens_pspec,
)

MESH = {"data": 16, "model": 16}


def test_data_deterministic_and_learnable():
    cfg = ModelConfig(vocab_size=64)
    b1 = next(batches(cfg, 4, 32, seed=3))
    b2 = next(batches(cfg, 4, 32, seed=3))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert b1["labels"].shape == (4, 32)
    # labels are next-token shifted
    stream = SyntheticLM(64, seed=0)
    toks = stream.sample(2, 16)
    assert toks.shape == (2, 17)
    # planted Markov structure: transition entropy < unigram entropy
    table = stream.table
    p = table.mean(axis=0)
    h_uni = -(p * np.log(p + 1e-12)).sum()
    h_cond = -(table * np.log(table + 1e-12)).sum(axis=1).mean()
    assert h_cond < h_uni - 0.1  # there is something to learn


def test_sampler_rounding_edge_clamps_to_last_token():
    """Regression: when float rounding leaves u >= cum[-1], the old
    `(u < cum).argmax` draw returned token 0 (argmax of all-False); the
    clamped searchsorted draw must land at the tail of the distribution."""
    stream = SyntheticLM(32, seed=0)

    class EdgeRng:
        """rand() returns 1.0 — beyond every row's cumsum — to force the edge."""

        def __init__(self, inner):
            self.inner = inner

        def rand(self, *shape):
            return np.ones(shape)

        def randint(self, *a, **k):
            return self.inner.randint(*a, **k)

    stream.rng = EdgeRng(stream.rng)
    toks = stream.sample(4, 8)
    assert (toks >= 0).all() and (toks < 32).all()
    # every draw hit the u >= cum[-1] edge: must clamp to the tail, never
    # fall back to token 0 (the most-probable Zipf head — a silent bias)
    assert (toks[:, 1:] != 0).all()
    assert (toks[:, 1:] >= 30).all()


def test_sampler_off_edge_draw_unchanged():
    """The searchsorted draw is the first index with cum > u — identical to
    the previous strict-inequality argmax away from the rounding edge, so
    fixed-seed token streams are preserved."""
    stream = SyntheticLM(64, seed=5)
    toks = stream.sample(8, 32)
    ref = SyntheticLM(64, seed=5)
    out = np.empty_like(toks)
    out[:, 0] = ref.rng.randint(0, 64, size=8)
    for t in range(32):
        cum = np.cumsum(ref._rows(out[:, t]), axis=1)
        u = ref.rng.rand(8, 1)
        old = (u < cum).argmax(axis=1)  # the pre-fix formula
        valid = (u < cum[:, -1:]).ravel()  # rows where it was well-defined
        new_draw = np.minimum((cum <= u).sum(axis=1), 63)
        np.testing.assert_array_equal(new_draw[valid], old[valid])
        out[:, t + 1] = new_draw
    np.testing.assert_array_equal(toks, out)


def test_data_modalities():
    audio = ModelConfig(vocab_size=32, num_codebooks=4)
    b = next(batches(audio, 2, 8))
    assert b["tokens"].shape == (2, 8, 4)
    vlm = ModelConfig(vocab_size=32, frontend="vision", frontend_tokens=3, frontend_dim=16)
    b = next(batches(vlm, 2, 8))
    assert b["frontend"].shape == (2, 3, 16)


def test_checkpoint_roundtrip(tmp_path):
    cfg = ModelConfig(
        num_layers=2, d_model=32, d_ff=64, vocab_size=64,
        attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = build_optimizer(
        OptimizerConfig(name="basis_rotation", total_steps=10), params, cfg, num_stages=2
    )
    state = opt.init(params)
    g = jax.tree.map(jnp.ones_like, params)
    _, state = opt.update(g, state, params, jnp.int32(0))
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, (params, state), step=7, meta={"note": "t"})
    (p2, s2), step, meta = load_checkpoint(path)
    assert step == 7 and meta["note"] == "t"
    assert jax.tree.structure((params, state)) == jax.tree.structure((p2, s2))
    for a, b in zip(jax.tree.leaves((params, state)), jax.tree.leaves((p2, s2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_interrupted_save_keeps_previous(tmp_path, monkeypatch):
    """A crash mid-save must not corrupt the only checkpoint: files are
    written to temp names and atomically swapped in (arrays first, manifest
    last), so the pre-crash checkpoint stays loadable."""
    path = str(tmp_path / "ckpt")
    tree1 = {"w": jnp.arange(4.0), "b": jnp.ones((2, 2))}
    save_checkpoint(path, tree1, step=1, meta={"note": "good"})

    # crash while writing the arrays file (partial bytes on disk, then die)
    def savez_boom(file, **kw):
        with open(file, "wb") as f:
            f.write(b"\x00partial-garbage")
        raise RuntimeError("simulated crash during array write")

    monkeypatch.setattr(np, "savez", savez_boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_checkpoint(path, {"w": jnp.arange(4.0) + 9, "b": jnp.zeros((2, 2))},
                        step=2)
    monkeypatch.undo()

    tree, step, meta = load_checkpoint(path)
    assert step == 1 and meta["note"] == "good"
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(4.0))

    # crash while serialising the manifest: same guarantee
    import json as _json

    real_dump = _json.dump

    def dump_boom(obj, f, **kw):
        f.write('{"spec": "trunc')
        raise RuntimeError("simulated crash during manifest write")

    monkeypatch.setattr(_json, "dump", dump_boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_checkpoint(path, tree1, step=3)
    monkeypatch.setattr(_json, "dump", real_dump)

    tree, step, _ = load_checkpoint(path)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["b"]), np.ones((2, 2)))

    # crash after the arrays file commits but before the manifest swap: the
    # old manifest still names the old arrays file — never a mixed state
    real_replace = os.replace

    def replace_boom(src, dst):
        if dst.endswith("manifest.json"):
            raise RuntimeError("simulated crash before manifest commit")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", replace_boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_checkpoint(path, {"w": jnp.arange(4.0) + 9, "b": jnp.zeros((2, 2))},
                        step=4)
    monkeypatch.setattr(os, "replace", real_replace)

    tree, step, _ = load_checkpoint(path)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(4.0))


def test_param_pspec_rules():
    assert param_pspec("embed/embedding", (64000, 7168), MESH) == P("model", "data")
    assert param_pspec("lm_head", (7168, 64000), MESH) == P("data", "model")
    assert param_pspec("blocks/0/mixer/w_q", (60, 7168, 7168), MESH) == P(None, "data", "model")
    assert param_pspec("blocks/0/mixer/w_o", (7168, 7168), MESH) == P("model", "data")
    # expert parallel when experts divide the axis
    assert param_pspec("blocks/0/mlp/w_gate_e", (160, 5120, 1536), MESH) == P("model", "data", None)
    # hidden-dim fallback when they don't (mixtral: 8 experts, 16-way axis)
    assert param_pspec("blocks/0/mlp/w_gate_e", (8, 6144, 16384), MESH) == P(None, "data", "model")
    assert param_pspec("blocks/0/mlp/w_down_e", (8, 16384, 6144), MESH) == P(None, "model", "data")
    # non-divisible dims degrade to None, norms replicated
    assert param_pspec("blocks/0/mixer/w_q", (100, 50), MESH) == P(None, None)
    assert param_pspec("blocks/0/norm1/scale", (7168,), MESH) == P(None)


def test_opt_state_pspecs_structure():
    cfg = ModelConfig(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),),
    )
    params = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    opt = build_optimizer(
        OptimizerConfig(name="basis_rotation", total_steps=10), params, cfg,
        num_stages=1, apply_delay=False,
    )
    st = jax.eval_shape(opt.init, params)
    specs = opt_state_pspecs(st, params, MESH)
    # every state leaf got a spec of matching rank
    flat_s = jax.tree.leaves(st)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for aval, spec in zip(flat_s, flat_p):
        assert isinstance(spec, P)
        assert len(spec) <= len(aval.shape)


def test_token_and_activation_specs():
    assert tokens_pspec(256, MESH) == P(("data",), None)
    assert tokens_pspec(7, MESH) == P(None, None)  # indivisible
    ms3 = {"pod": 2, "data": 16, "model": 16}
    assert tokens_pspec(256, ms3) == P(("pod", "data"), None)
    spec = generic_activation_pspec((128, 8, 32768, 128), MESH, batch_dim=0)
    assert spec[0] in ("data", ("data",)) and "model" in spec
