"""Hypothesis property tests for the paper's theoretical invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional dependency (the `test` extra in pyproject.toml): skip this module
# instead of aborting the whole collection when hypothesis is absent
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.rotation import power_qr
from repro.core.theory import effective_delay, norm_11, rotated_hessian

jax.config.update("jax_enable_x64", False)


def _psd(seed: int, n: int):
    g = np.random.RandomState(seed).randn(n, n).astype(np.float32)
    return jnp.asarray(g @ g.T + 0.1 * np.eye(n, dtype=np.float32))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(2, 6), n=st.integers(2, 6))
def test_theorem_3_1_inequality_chain(seed, m, n):
    """||H_{U,V}||_11 <= ||H_U||_11 <= ||H||_11 for Kronecker H = A (x) B with
    U, V the exact eigenvectors of B, A (Theorem 3.1)."""
    A = _psd(seed, n)
    Bm = _psd(seed + 1, m)
    H = jnp.kron(A, Bm)
    _, U = jnp.linalg.eigh(Bm)  # rows <-> B (m x m)
    _, V = jnp.linalg.eigh(A)
    h = float(norm_11(H))
    h_u = float(norm_11(rotated_hessian(H, U, None)))
    h_uv = float(norm_11(rotated_hessian(H, U, V)))
    tol = 1e-3 * max(h, 1.0)
    assert h_uv <= h_u + tol
    assert h_u <= h + tol
    # bilateral achieves (near-)diagonal: compare against the true minimum
    diag_min = float(jnp.sum(jnp.abs(jnp.linalg.eigvalsh(H))))
    assert abs(h_uv - diag_min) <= 1e-2 * max(diag_min, 1.0)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 16))
def test_effective_delay_bounds(seed, k):
    """tau' <= max(tau) and tau' >= min(tau) (Theorem E.6)."""
    rng = np.random.RandomState(seed)
    c_sq = jnp.asarray(rng.rand(k).astype(np.float32) + 1e-3)
    taus = jnp.asarray(rng.randint(0, 32, size=k).astype(np.float32))
    t = float(effective_delay(c_sq, taus))
    assert t <= float(jnp.max(taus)) + 1e-4
    assert t >= float(jnp.min(taus)) - 1e-4


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_effective_delay_decreases_when_early_stage_c_shrinks(seed):
    """Suppressing misalignment mass at the MOST delayed stage reduces tau' —
    the theoretical justification for stage-aware rotation (Section 4.3)."""
    rng = np.random.RandomState(seed)
    k = 8
    c_sq = rng.rand(k).astype(np.float32) + 0.1
    taus = np.asarray([k - 1 - i for i in range(k)], np.float32)
    base = float(effective_delay(jnp.asarray(c_sq), jnp.asarray(taus)))
    damped = c_sq.copy()
    damped[0] *= 0.1  # stage 0 has the largest delay
    out = float(effective_delay(jnp.asarray(damped), jnp.asarray(taus)))
    assert out <= base + 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 24))
def test_power_qr_keeps_orthonormality(seed, n):
    A = _psd(seed, n)
    U = jnp.eye(n)
    for _ in range(3):
        U = power_qr(A, U)
    err = jnp.max(jnp.abs(U.T @ U - jnp.eye(n)))
    assert float(err) < 1e-4


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 10))
def test_norm11_minimised_by_eigenbasis(seed, n):
    """For symmetric H, rotating by the eigenbasis minimises the (1,1)-norm
    (diagonal case) vs. random orthogonal rotations."""
    H = _psd(seed, n)
    w, Q = jnp.linalg.eigh(H)
    diag = float(jnp.sum(jnp.abs(w)))
    rng = np.random.RandomState(seed + 7)
    R = jnp.asarray(np.linalg.qr(rng.randn(n, n))[0].astype(np.float32))
    rotated = float(norm_11(R.T @ H @ R))
    assert diag <= rotated + 1e-3 * max(rotated, 1.0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_delay_fifo_exact_semantics(seed):
    """The FIFO wrapper applies at step t exactly the gradient from t - tau."""
    from repro.optim.base import Optimizer
    from repro.pipeline.delay import delayed_optimizer

    captured = []

    def rec_update(grads, state, params, step, aux=None):
        captured.append(np.asarray(grads["w"]).copy())
        return jax.tree.map(jnp.zeros_like, grads), state

    recorder = Optimizer(lambda p: {}, rec_update)
    tau = 3
    wrapped = delayed_optimizer(recorder, [tau])
    params = {"w": jnp.zeros((4,))}
    state = wrapped.init(params)
    rng = np.random.RandomState(seed)
    gs = [jnp.asarray(rng.randn(4).astype(np.float32)) for _ in range(8)]
    for t, g in enumerate(gs):
        _, state = wrapped.update({"w": g}, state, params, jnp.int32(t))
    for t in range(8):
        if t < tau:
            assert np.allclose(captured[t], 0.0)  # warm-up: nothing arrived yet
        else:
            assert np.allclose(captured[t], np.asarray(gs[t - tau]))
