"""Core rotation machinery: power iteration, rotations, the optimizer's
algebraic invariants, stage-aware frequencies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    basis_rotation_adam,
    build_layout,
    power_qr,
    rotate,
    rotated_fraction,
    unrotate,
)
from repro.core.rotation import batched_eye, gram_left, gram_right, refresh_basis
from repro.core.stage_aware import (
    NEVER,
    StageContext,
    budget,
    freqs_for_delays,
    stage_aware_freq,
)
from repro.optim import adam, constant_schedule


def test_power_qr_orthonormal_and_converges():
    key = jax.random.PRNGKey(0)
    Q0 = jnp.linalg.qr(jax.random.normal(key, (16, 16)))[0]
    A = Q0 @ jnp.diag(jnp.linspace(10, 0.1, 16)) @ Q0.T  # PSD, known eigvecs
    U = jnp.eye(16)
    for _ in range(60):
        U = power_qr(A, U)
    assert np.allclose(U.T @ U, np.eye(16), atol=1e-5)
    # subspace alignment: |<u_i, q_i>| -> 1
    overlap = jnp.abs(jnp.sum(U * Q0, axis=0))
    assert float(jnp.min(overlap)) > 0.99


def test_power_qr_batched():
    key = jax.random.PRNGKey(1)
    A = jax.random.normal(key, (3, 8, 8))
    A = jnp.einsum("bij,bkj->bik", A, A)  # PSD batch
    U = batched_eye(8, (3,))
    U = power_qr(A, U)
    eye_err = jnp.einsum("bji,bjk->bik", U, U) - jnp.eye(8)
    assert float(jnp.max(jnp.abs(eye_err))) < 1e-5


def test_rotate_unrotate_inverse():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (12, 20))
    U = jnp.linalg.qr(jax.random.normal(key, (12, 12)))[0]
    V = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(3), (20, 20)))[0]
    np.testing.assert_allclose(
        np.asarray(unrotate(rotate(x, U, V), U, V)), np.asarray(x), atol=1e-5
    )
    # Frobenius norm preserved (orthogonality)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(rotate(x, U, V))), float(jnp.linalg.norm(x)), rtol=1e-5
    )


def test_identity_rotation_is_adam():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (16, 24)), "scale": jnp.ones((24,))}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (16, 24)),
             "scale": jnp.ones((24,)) * 0.1}
    sched = constant_schedule(1e-2)
    br, ad = basis_rotation_adam(sched, freq=0), adam(sched)
    s1, s2 = br.init(params), ad.init(params)
    for t in range(4):
        u1, s1 = br.update(grads, s1, params, jnp.int32(t))
        u2, s2 = ad.update(grads, s2, params, jnp.int32(t))
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), u1, u2)
        assert max(jax.tree.leaves(d)) < 1e-6


def test_rotation_equivariance():
    """Basis rotation with the TRUE eigenbasis of a rotated quadratic matches
    plain Adam on the axis-aligned version of the same problem."""
    key = jax.random.PRNGKey(0)
    d = 8
    Q = jnp.linalg.qr(jax.random.normal(key, (d, d)))[0]
    diag = jnp.linspace(10.0, 0.5, d)

    # aligned problem: f(w) = 1/2 w^T D w ; rotated: g(x) = f(Q^T x)
    w = jax.random.normal(jax.random.PRNGKey(1), (1, d))  # as a 1 x d matrix
    x = w @ Q.T  # rotated iterate

    sched = constant_schedule(0.1)
    ad = adam(sched, beta1=0.0)
    br = basis_rotation_adam(sched, beta1=0.0, freq=0, min_dim=1)
    sa = ad.init({"w": w})
    sb = br.init({"x": x})
    # manually install the true eigenbasis (right rotation of the 1 x d
    # iterate: x_tilde = x V with V = Q maps to the aligned coordinates)
    sb["leaves"][0]["V"] = Q

    for t in range(20):
        gw = w * diag  # grad of aligned quadratic
        gx = (x @ Q) * diag @ Q.T  # grad of rotated quadratic
        uw, sa = ad.update({"w": gw}, sa, {"w": w}, jnp.int32(t))
        ux, sb = br.update({"x": gx}, sb, {"x": x}, jnp.int32(t))
        w = w + uw["w"]
        x = x + ux["x"]
        # the rotated trajectory tracks the aligned one exactly
        np.testing.assert_allclose(np.asarray(x @ Q), np.asarray(w), atol=1e-4)


def test_layout_exclusions_and_sides():
    params = {
        "embed": {"embedding": jnp.zeros((64, 16))},
        "lm_head": jnp.zeros((16, 64)),
        "blocks": [{"norm1": {"scale": jnp.zeros((16,))},
                    "mixer": {"w_q": jnp.zeros((16, 32)), "b_q": jnp.zeros((32,))},
                    "mlp": {"w_down": jnp.zeros((32, 16))}}],
    }
    lay = {p.path: p for p in build_layout(params, "unilateral")}
    assert not lay["embed/embedding"].rotate
    assert not lay["lm_head"].rotate
    assert not lay["blocks/0/norm1/scale"].rotate
    assert not lay["blocks/0/mixer/b_q"].rotate
    wq = lay["blocks/0/mixer/w_q"]
    assert wq.rotate and wq.left and not wq.right  # smaller dim = rows
    wd = lay["blocks/0/mlp/w_down"]
    assert wd.rotate and not wd.left and wd.right
    bi = {p.path: p for p in build_layout(params, "bilateral")}
    assert bi["blocks/0/mixer/w_q"].left and bi["blocks/0/mixer/w_q"].right
    frac = rotated_fraction(params, build_layout(params, "bilateral"))
    assert 0.0 < frac < 1.0


def test_refresh_sources_state():
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 12))
    m = 0.5 * g
    U, V = batched_eye(8, ()), batched_eye(12, ())
    L, R = jnp.zeros((8, 8)), jnp.zeros((12, 12))
    U2, V2, L2, R2 = refresh_basis(g, m, U, V, L, R, "2nd", 0.9)
    assert float(jnp.max(jnp.abs(L2 - 0.1 * gram_left(g)))) < 1e-5
    assert float(jnp.max(jnp.abs(R2 - 0.1 * gram_right(g)))) < 1e-5
    U1, V1, L1, R1 = refresh_basis(g, m, U, V, None, None, "1st", 0.9)
    assert L1 is None and R1 is None  # no Fisher state for S=1st


def test_stage_aware_rule():
    P, f0 = 8, 10
    freqs = [stage_aware_freq(tau, P, f0) for tau in range(P)]
    # most-delayed stages refresh most often
    assert freqs[P - 1] < f0
    # least-delayed stages never refresh
    assert freqs[0] == NEVER and freqs[1] == NEVER
    # monotone: more delay => more frequent (smaller period), among finite
    finite = [f for f in freqs if f < NEVER]
    assert finite == sorted(finite, reverse=True)
    # budget-normalised allocation never exceeds the uniform budget
    norm = freqs_for_delays(list(range(P)), P, f0)
    assert budget(norm, 1000) <= budget([f0] * P, 1000) + 1e-6


def test_stage_aware_reversed_allocation():
    delays = [3, 2, 1, 0]
    fwd = freqs_for_delays(delays, 4, 10)
    rev = freqs_for_delays(delays, 4, 10, reversed_allocation=True)
    assert fwd == list(reversed(rev))


def test_per_stage_refresh_mask_selective():
    """A stacked (K, m, n) leaf with per-stage periods [1, NEVER] refreshes
    exactly stage 0's basis every step; stage 1's basis stays identity."""
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (2, 16, 16))}
    opt = basis_rotation_adam(constant_schedule(1e-2), freq=[(1, NEVER)])
    s = opt.init(params)
    eye = jnp.eye(16)
    for t in range(3):
        g = {"w": jax.random.normal(jax.random.PRNGKey(10 + t), (2, 16, 16))}
        _, s = opt.update(g, s, params, jnp.int32(t))
        U, V = s["leaves"][0]["U"], s["leaves"][0]["V"]
        assert float(jnp.max(jnp.abs(U[0] - eye))) > 1e-3, f"step {t}"
        np.testing.assert_array_equal(np.asarray(U[1]), np.asarray(eye))
        np.testing.assert_array_equal(np.asarray(V[1]), np.asarray(eye))
        # the non-refreshing stage's Fisher EMA must not advance either
        np.testing.assert_array_equal(
            np.asarray(s["leaves"][0]["L"][1]), np.zeros((16, 16), np.float32)
        )


def test_per_stage_uniform_freqs_match_scalar_path():
    """The vectorized per-stage mask with one period on every stage must
    reproduce the scalar lax.cond path (the sim backend's entry) exactly."""
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (2, 16, 24))}
    sched = constant_schedule(1e-2)
    opt_scalar = basis_rotation_adam(sched, freq=3)
    opt_tuple = basis_rotation_adam(sched, freq=[(3, 3)])
    s1, s2 = opt_scalar.init(params), opt_tuple.init(params)
    for t in range(7):
        g = {"w": jax.random.normal(jax.random.PRNGKey(20 + t), (2, 16, 24))}
        u1, s1 = opt_scalar.update(g, s1, params, jnp.int32(t))
        u2, s2 = opt_tuple.update(g, s2, params, jnp.int32(t))
        np.testing.assert_array_equal(np.asarray(u1["w"]), np.asarray(u2["w"]))


def test_never_freq_never_refreshes():
    """Periods >= NEVER mean literally never — including step 0 — so the
    'never refresh' stages of the stage-aware allocation keep identity bases
    on both the scalar and the vectorized path."""
    key = jax.random.PRNGKey(3)
    params = {"w": jax.random.normal(key, (16, 16))}
    opt = basis_rotation_adam(constant_schedule(1e-2), freq=NEVER)
    s = opt.init(params)
    g = {"w": jax.random.normal(jax.random.PRNGKey(4), (16, 16))}
    _, s = opt.update(g, s, params, jnp.int32(0))
    np.testing.assert_array_equal(
        np.asarray(s["leaves"][0]["U"]), np.asarray(jnp.eye(16))
    )


def test_stage_context_freqs_match_sim_multiset():
    """The stacked layout's per-stage periods equal the per-layer sim
    layout's: budget renormalisation over the expanded canonical multiset
    assigns the same period to the same delay on both layouts."""
    K, per = 4, 3
    # sim layout: per-layer scalar leaves (K*per block leaves + 2 shared)
    sim_delays = tuple(
        K - 1 - (l // per) for l in range(K * per)
    ) + (K - 1, 0)
    ctx_sim = StageContext(K, sim_delays, (1,) * len(sim_delays))
    # stacked layout: one (K, per, ...) leaf + the same 2 shared leaves
    stage_delays = tuple(K - 1 - k for k in range(K))
    ctx_stacked = StageContext(K, (stage_delays, K - 1, 0), (per, 1, 1))
    for base in (2, 5, 10):
        fs = ctx_sim.refresh_freqs(base)
        fstk = ctx_stacked.refresh_freqs(base)
        lut_sim = dict(zip(sim_delays, fs))
        lut_stk = dict(zip(stage_delays, fstk[0]))
        for tau in stage_delays:
            assert lut_sim[tau] == lut_stk[tau], (base, tau)
        assert fstk[1] == lut_sim[K - 1] and fstk[2] == lut_sim[0]


def test_stage_context_delay_specs_and_scales():
    ctx = StageContext(3, ((2, 1, 0), 2, 0), (2, 1, 1))
    assert ctx.delay_specs() == ["stage", 2, 0]
    params = (jnp.zeros((3, 2, 4, 4)), {"e": jnp.zeros((4,)), "h": jnp.zeros((4,))})
    scales = ctx.delay_scales(params)
    assert scales[0].shape == (3, 1, 1, 1)
    np.testing.assert_array_equal(
        np.asarray(scales[0]).reshape(-1), np.asarray([2.0, 1.0, 0.0])
    )
    assert scales[1]["e"] == 2 and scales[1]["h"] == 0
