"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step and one decode step on CPU,
asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, PAPER_IDS, get_config
from repro.configs.catalog import shapes_for
from repro.data import batches
from repro.models import (
    forward_decode,
    init_cache,
    init_model,
    loss_fn,
    param_count,
)

SMOKE_B, SMOKE_S = 2, 32


@pytest.mark.parametrize("arch", ARCH_IDS + PAPER_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.d_model <= 512 and cfg.num_layers <= 2
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    params = init_model(jax.random.PRNGKey(0), cfg)
    assert param_count(params) > 0
    batch = next(batches(cfg, SMOKE_B, SMOKE_S, seed=0))
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch
    )
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert all(jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)), (
        f"{arch}: non-finite grads"
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, SMOKE_B, SMOKE_S)
    if cfg.num_codebooks > 1:
        tok = jnp.zeros((SMOKE_B, 1, cfg.num_codebooks), jnp.int32)
        want = (SMOKE_B, 1, cfg.num_codebooks, cfg.vocab_size)
    else:
        tok = jnp.zeros((SMOKE_B, 1), jnp.int32)
        want = (SMOKE_B, 1, cfg.vocab_size)
    logits, new_cache = forward_decode(params, cfg, tok, cache, jnp.int32(0))
    assert logits.shape == want
    assert jnp.all(jnp.isfinite(logits)), f"{arch}: non-finite decode logits"
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned dimensions (never built)."""
    cfg = get_config(arch)
    expected = {
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "stablelm_1_6b": (24, 2048, 32, 32, 5632, 100352),
        "qwen3_0_6b": (28, 1024, 16, 8, 3072, 151936),
        "qwen1_5_0_5b": (24, 1024, 16, 16, 2816, 151936),
        "phi4_mini_3_8b": (32, 3072, 24, 8, 8192, 200064),
        "jamba_v0_1_52b": (32, 4096, 32, 8, 14336, 65536),
        "deepseek_v2_236b": (60, 5120, 128, 128, 1536, 102400),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    L, d, H, kv, dff, V = expected
    assert cfg.num_layers == L and cfg.d_model == d and cfg.vocab_size == V
    assert cfg.d_ff == dff
    if cfg.ssm is not None and cfg.family == "ssm":
        assert cfg.ssm.num_heads == H
    else:
        assert cfg.attention.num_heads == H
        assert cfg.attention.num_kv_heads == kv
    assert cfg.source, f"{arch}: missing citation"


def test_moe_extras():
    mix = get_config("mixtral_8x22b")
    assert mix.moe.num_experts == 8 and mix.moe.top_k == 2
    assert mix.attention.window == 4096  # SWA
    ds = get_config("deepseek_v2_236b")
    assert ds.moe.num_experts == 160 and ds.moe.top_k == 6 and ds.moe.num_shared == 2
    assert ds.attention.kind == "mla" and ds.attention.kv_lora_rank == 512
    jm = get_config("jamba_v0_1_52b")
    assert jm.moe.num_experts == 16 and jm.moe.top_k == 2
    mixers = [s.mixer for s in jm.pattern]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7  # 1:7


def test_long_context_policy():
    names = {a: [s.name for s in shapes_for(get_config(a))] for a in ARCH_IDS}
    for a in ("mixtral_8x22b", "jamba_v0_1_52b", "xlstm_1_3b"):
        assert "long_500k" in names[a], a
    for a in ("llava_next_34b", "stablelm_1_6b", "qwen3_0_6b", "qwen1_5_0_5b",
              "phi4_mini_3_8b", "musicgen_large", "deepseek_v2_236b"):
        assert "long_500k" not in names[a], a
    swa = get_config("phi4_mini_3_8b_swa")
    assert swa.supports_long_context()  # beyond-paper SWA variant
