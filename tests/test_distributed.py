"""Multi-controller deployment: process grids, device bootstrap, per-process
checkpoint shard ownership, and real 2-process `jax.distributed` runs.

The subprocess tests at the bottom fork REAL OS processes through
`repro.launch.spawn` (gloo CPU collectives) — the same path CI's
multi-process smoke step runs — and are the slowest tests in the suite; the
unit tests above them cover the pure mapping logic without touching jax
device state.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_sharded_checkpoint
from repro.launch.devices import ensure_host_devices
from repro.launch.distributed import (
    ENV_COORDINATOR,
    ENV_NUM_PROCESSES,
    ENV_PROCESS_ID,
    ProcessGrid,
    distributed_env,
)
from repro.launch.topology import Topology

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- ProcessGrid / env contract ---------------------------------------------

def test_process_grid_validation():
    g = ProcessGrid()
    assert g.num_processes == 1 and g.process_index == 0 and not g.distributed
    assert ProcessGrid(4, 3, "h:1").distributed
    with pytest.raises(ValueError):
        ProcessGrid(0, 0)
    with pytest.raises(ValueError):
        ProcessGrid(2, 2, "h:1")


def test_distributed_env_contract():
    assert distributed_env(env={}) is None
    env = {ENV_COORDINATOR: "127.0.0.1:9", ENV_NUM_PROCESSES: "2",
           ENV_PROCESS_ID: "1"}
    g = distributed_env(env=env)
    assert (g.num_processes, g.process_index, g.coordinator) == (
        2, 1, "127.0.0.1:9")
    # a partial contract is a launcher bug, not a single-process run
    with pytest.raises(RuntimeError):
        distributed_env(env={ENV_NUM_PROCESSES: "2"})


# -- ensure_host_devices (satellite: shared XLA_FLAGS bootstrap) -------------

def test_ensure_host_devices_appends_without_clobbering():
    env = {"XLA_FLAGS": "--xla_dump_to=/tmp/d"}
    assert ensure_host_devices(8, env=env)
    assert env["XLA_FLAGS"] == (
        "--xla_dump_to=/tmp/d --xla_force_host_platform_device_count=8")


def test_ensure_host_devices_first_setter_wins():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    assert not ensure_host_devices(8, env=env)
    assert env["XLA_FLAGS"] == "--xla_force_host_platform_device_count=4"


def test_ensure_host_devices_defers_to_accelerators():
    for var in ("JAX_PLATFORMS", "JAX_PLATFORM_NAME"):
        env = {var: "tpu"}
        assert not ensure_host_devices(8, env=env)
        assert "XLA_FLAGS" not in env
    # cpu is not an accelerator: the flag applies
    env = {"JAX_PLATFORMS": "cpu"}
    assert ensure_host_devices(8, env=env)
    with pytest.raises(ValueError):
        ensure_host_devices(0, env={})


def test_spawn_worker_env_strips_global_device_force(monkeypatch):
    """spawn workers re-derive their LOCAL device share; an outer harness's
    global count must not leak through XLA_FLAGS (but user flags survive)."""
    from repro.launch.spawn import worker_env

    monkeypatch.setenv(
        "XLA_FLAGS",
        "--xla_dump_to=/tmp/d --xla_force_host_platform_device_count=16")
    env = worker_env(2, 1, "127.0.0.1:5")
    assert "xla_force_host_platform_device_count" not in env.get("XLA_FLAGS", "")
    assert "--xla_dump_to=/tmp/d" in env["XLA_FLAGS"]
    assert env[ENV_NUM_PROCESSES] == "2" and env[ENV_PROCESS_ID] == "1"
    assert env[ENV_COORDINATOR] == "127.0.0.1:5"
    assert env["PYTHONPATH"].startswith(os.path.join(REPO, "src"))


# -- Topology process grid ---------------------------------------------------

def test_process_data_shards_pod_split():
    """2x2x1 over 2 processes: each process is one pod = one data shard."""
    topo = Topology(stages=2, data=1, pods=2)
    assert topo.local_device_count(2) == 2
    assert topo.process_data_shards(2, 0) == (0, 1)
    assert topo.process_data_shards(2, 1) == (1, 2)


def test_process_data_shards_stage_split_overlaps():
    """(stages=2, data=1) over 2 processes: both hold stage replicas of the
    SAME batch rows — overlapping full ranges, the assembly API's contract
    for replicated-in-data layouts."""
    topo = Topology(stages=2, data=1)
    assert topo.process_data_shards(2, 0) == (0, 1)
    assert topo.process_data_shards(2, 1) == (0, 1)


def test_process_data_shards_data_split():
    """(stages=2, data=4) over 4 processes: slabs of 2 devices cut each
    stage's data extent in half."""
    topo = Topology(stages=2, data=4)
    assert [topo.process_data_shards(4, p) for p in range(4)] == [
        (0, 2), (2, 4), (0, 2), (2, 4)]


def test_process_data_shards_misaligned_raises():
    """A slab straddling a stage boundary mid-row owns non-contiguous data
    shards — rejected loudly instead of silently mis-feeding rows."""
    topo = Topology(stages=2, data=3)
    with pytest.raises(ValueError, match="non-contiguous"):
        topo.process_data_shards(3, 1)
    with pytest.raises(ValueError):
        topo.local_device_count(4)  # 6 devices don't split over 4


@pytest.mark.parametrize("topo,procs", [
    (Topology(stages=2, data=1, pods=2), 2),
    (Topology(stages=2, data=2), 2),
    (Topology(stages=4, data=1), 2),
    (Topology(stages=2, data=2, pods=2), 4),
    (Topology(stages=2, data=2, pods=2), 2),
])
def test_shard_owners_partition(topo, procs):
    """Ownership invariants for every launcher-producible layout: exactly
    one owner per checkpoint shard, and the owner's device slab actually
    addresses that stage's slice."""
    owners = topo.shard_owners(procs)
    assert len(owners) == topo.stages
    stage_pos = 0 if topo.pods == 1 else 1
    for s, p in enumerate(owners):
        assert 0 <= p < procs
        coords = topo._process_coords(procs, p)
        assert s in set(int(c[stage_pos]) for c in coords)
    # pod-replicated layouts spread writes over the replicas
    if topo.pods > 1 and procs >= topo.pods and topo.stages > 1:
        assert len(set(owners)) > 1


# -- per-process checkpoint shard writes (single-process harness) ------------

def _tree():
    return {"b": np.arange(3, dtype=np.float32),
            "w": np.arange(8, dtype=np.float32).reshape(2, 4)}


def test_sharded_checkpoint_nonmain_writes_no_manifest(tmp_path):
    """A non-main process flushes ONLY its own shard file — no manifest, no
    replicated leaves (those are shard 0's), no temp leftovers."""
    path = str(tmp_path / "ckpt")
    save_sharded_checkpoint(path, _tree(), num_shards=2, step=3,
                            shard_axes=[None, 0], owned_shards=[1],
                            write_manifest=False)
    assert sorted(os.listdir(path)) == [
        "arrays-00000003-shard00001-of-00002.npz"]


def test_sharded_checkpoint_concurrent_ownership_split(tmp_path):
    """Two 'processes' (threads sharing a real barrier, so both scan the
    directory before either writes — the actual multi-controller protocol):
    each writes only its owned shard, one commits the manifest, and the
    result loads identically to a single-controller save."""
    import threading

    path = str(tmp_path / "ckpt")
    bar = threading.Barrier(2, timeout=60)
    errs = []

    def save(owned, manifest):
        try:
            save_sharded_checkpoint(
                path, _tree(), num_shards=2, step=3, shard_axes=[None, 0],
                owned_shards=owned, write_manifest=manifest,
                barrier=lambda name: bar.wait())
        except Exception as e:  # surfaced below — threads swallow raises
            errs.append(e)

    t = threading.Thread(target=save, args=([1], False))
    t.start()
    save([0], True)
    t.join()
    assert not errs, errs
    tree, step, _ = load_checkpoint(path)
    assert step == 3
    np.testing.assert_array_equal(tree["w"], _tree()["w"])
    np.testing.assert_array_equal(tree["b"], _tree()["b"])


def test_sharded_checkpoint_gc_respects_ownership(tmp_path):
    """GC after a commit only collects files whose shard index the process
    owns — never a peer's files, even stale ones."""
    path = str(tmp_path / "ckpt")
    os.makedirs(path)
    stale_mine = "arrays-00000001-shard00000-of-00002.npz"
    stale_peer = "arrays-00000001-shard00001-of-00002.npz"
    for n in (stale_mine, stale_peer):
        np.savez(os.path.join(path, n), x=np.zeros(1))
    save_sharded_checkpoint(path, _tree(), num_shards=2, step=2,
                            shard_axes=[None, 0], owned_shards=[0],
                            write_manifest=True)
    names = set(os.listdir(path))
    assert stale_mine not in names  # superseded + owned: collected
    assert stale_peer in names      # peer's file: untouchable


def test_barriers_invoked_in_order(tmp_path):
    """The three-phase barrier protocol (names gen -> shards -> commit) is
    what keeps multi-process saves atomic; assert the callable sees it."""
    calls = []
    save_sharded_checkpoint(str(tmp_path / "c"), _tree(), num_shards=2,
                            step=7, shard_axes=[None, 0],
                            owned_shards=[0, 1], write_manifest=True,
                            barrier=calls.append)
    assert calls == ["ckpt-7-g0-named", "ckpt-7-g0-shards", "ckpt-7-g0-commit"]


# -- real 2-process jax.distributed runs (spawn) -----------------------------

TRAIN_ARGS = ("--backend spmd --smoke --arch paper_95m --optimizer adam "
              "--batch 4 --seq 32 --lr 1e-3 --log-every 2 --steps 8 "
              "--ckpt-every 4")


def _spawn(extra, train_args, timeout=840):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.spawn", *extra, "--",
           *train_args.split()]
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(cmd, capture_output=True, text=True, cwd=REPO,
                          env=env, timeout=timeout)


def test_spawn_two_process_bitwise_resume_after_pod_loss(tmp_path):
    """End-to-end multi-controller acceptance: a 2-process (stage-split)
    run writes per-process shard files; killing a process after the step-4
    checkpoint commits and relaunching the SAME topology resumes bit-
    identically — the merged metrics series equals the uninterrupted run's
    bit for bit."""
    ref_out = str(tmp_path / "ref.json")
    args = f"{TRAIN_ARGS} --stages 2"
    out = _spawn(["--procs", "2", "--timeout", "780"],
                 f"{args} --out {ref_out}")
    assert out.returncode == 0, out.stderr[-3000:]
    ref = json.load(open(ref_out))["losses"]
    assert len(ref) == 8

    ckpt = str(tmp_path / "ckpt")
    res_out = str(tmp_path / "res.json")
    run_args = f"{args} --ckpt-dir {ckpt} --out {res_out}"
    out = _spawn(["--procs", "2", "--timeout", "780", "--kill-pod-at", "4",
                  "--grace", "8", "--resume-procs", "2",
                  "--resume-with", run_args],
                 run_args)
    assert out.returncode == 0, out.stderr[-3000:]

    res = json.load(open(res_out))
    assert res["steps_done"] == 8 and res["start_step"] == 0
    assert res["losses"] == ref, (res["losses"], ref)

    # per-process on-disk format: one file per stage shard, main-only manifest
    manifest = json.load(open(os.path.join(ckpt, "manifest.json")))
    assert manifest["num_shards"] == 2
    assert manifest["meta"]["num_processes"] == 2
    for f in manifest["shard_files"]:
        assert os.path.exists(os.path.join(ckpt, f))


def test_spawn_elastic_resume_on_smaller_topology(tmp_path):
    """Elastic topology: lose a pod of a 2-process (pods=2, stages=2) run
    mid-flight, resume a SINGLE process on the shrunk (stages=2) topology
    from the sharded checkpoint (re-shard-on-load). The resumed metrics
    series must be continuous over the full step range and keep training."""
    ckpt = str(tmp_path / "ckpt")
    out_json = str(tmp_path / "m.json")
    phase1 = (f"{TRAIN_ARGS} --stages 2 --pods 2 --data-par 1 "
              f"--ckpt-dir {ckpt} --out {out_json}")
    phase2 = (f"{TRAIN_ARGS} --stages 2 --ckpt-dir {ckpt} --out {out_json}")
    out = _spawn(["--procs", "2", "--timeout", "780", "--kill-pod-at", "4",
                  "--grace", "8", "--resume-procs", "1",
                  "--resume-with", phase2],
                 phase1)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "resumed from" in out.stdout, out.stdout[-2000:]

    m = json.load(open(out_json))
    # continuous absolute-step series across the topology change
    assert m["start_step"] == 0 and m["steps_done"] == 8
    losses = m["losses"]
    assert len(losses) == 8
    assert all(np.isfinite(losses)), losses
    # it kept learning through the resume, and the post-resume segment
    # continues the pre-loss trend rather than restarting from init
    assert losses[-1] < losses[0] - 1.0, losses
    assert abs(losses[4] - losses[3]) < 0.5, losses
