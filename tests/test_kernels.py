"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles,
executed in interpret mode (kernel bodies run in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "shape", [(128, 128, 128), (256, 384, 128), (64, 256, 64), (100, 50, 70), (17, 33, 65)]
)
def test_matmul_sweep(shape, dtype):
    M, K, N = shape
    a = jax.random.normal(KEY, (M, K)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N)).astype(dtype)
    out = ops.pallas_matmul(a, b)
    want = ref.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("batch", [(), (3,), (2, 2)])
@pytest.mark.parametrize("sides", ["bi", "left", "right"])
def test_two_sided_rotate_sweep(batch, sides):
    m, n = 48, 32
    x = jax.random.normal(KEY, batch + (m, n))
    U = jnp.linalg.qr(jax.random.normal(KEY, batch + (m, m)))[0] if sides != "right" else None
    V = (
        jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(2), batch + (n, n)))[0]
        if sides != "left"
        else None
    )
    for transpose in (True, False):
        out = ops.two_sided_rotate(x, U, V, transpose=transpose)
        want = ref.two_sided_rotate_ref(x, U, V, transpose)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("shape", [(64, 64), (3, 100, 70), (2, 2, 40, 24)])
def test_fused_adam_scale_sweep(shape):
    g = jax.random.normal(KEY, shape)
    m = jax.random.normal(jax.random.PRNGKey(1), shape)
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), shape)) + 0.1
    s1, v1 = ops.adam_scale(g, m, v, 0.999, 1e-8, 0.5, 0.25)
    s2, v2 = ref.fused_adam_scale_ref(g, m, v, 0.999, 1e-8, 0.5, 0.25)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape,blocks", [((64, 64), (64, 64)),
                                          ((100, 70), (64, 64)),
                                          ((17, 33), (256, 256))])
def test_fused_adam_scale_kernel_parity_interpret(shape, blocks):
    """The Pallas kernel body itself (interpret mode, padded tiles included)
    against the ref.py oracle — guards the kernel's arithmetic, not just the
    ops.py wrapper: the step denominator must be sqrt(v/bc2) + eps exactly."""
    g = jax.random.normal(KEY, shape)
    m = jax.random.normal(jax.random.PRNGKey(1), shape)
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), shape)) + 1e-4
    from repro.kernels.adam_step import fused_adam_scale

    s1, v1 = fused_adam_scale(g, m, v, 0.999, 1e-8, 0.9, 0.1,
                              block_r=blocks[0], block_c=blocks[1],
                              interpret=True)
    s2, v2 = ref.fused_adam_scale_ref(g, m, v, 0.999, 1e-8, 0.9, 0.1)
    # scalars reach the kernel as fp32 (SMEM), so (1 - beta2) differs from
    # the reference's double constant in the last ulp — tolerance covers
    # that, not an algorithmic gap
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("window", [None, 100, 32])
@pytest.mark.parametrize("S,bq,bk", [(256, 64, 64), (128, 128, 32)])
def test_flash_attention_sweep(window, S, bq, bk):
    B, H, dh = 2, 3, 64
    q = jax.random.normal(KEY, (B, H, S, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, dh))
    out = ops.attention(q, k, v, window=window, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-3, atol=2e-4)


def test_flash_attention_bf16():
    B, H, S, dh = 1, 2, 128, 64
    q = jax.random.normal(KEY, (B, H, S, dh)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, dh)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, dh)).astype(jnp.bfloat16)
    out = ops.attention(q, k, v, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-2, atol=3e-2)


def test_kernel_backed_rotation_matches_reference_path():
    """Full basis-rotation step with kernels == pure-jnp path, on a
    well-conditioned state (v warmed so the step isn't 0/0-sensitive)."""
    from repro.core import basis_rotation_adam
    from repro.optim import constant_schedule

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (64, 48))}
    sched = constant_schedule(1e-2)
    b_ref = basis_rotation_adam(sched, freq=2, use_kernels=False)
    b_ker = basis_rotation_adam(sched, freq=2, use_kernels=True)
    s1, s2 = b_ref.init(params), b_ker.init(params)
    # warm v so denominators are well-conditioned
    for leaf in (s1["leaves"][0], s2["leaves"][0]):
        leaf["v"] = jnp.ones_like(leaf["v"])
    for t in range(4):
        g = {"w": jax.random.normal(jax.random.PRNGKey(10 + t), (64, 48))}
        u1, s1 = b_ref.update(g, s1, params, jnp.int32(t))
        u2, s2 = b_ker.update(g, s2, params, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(u1["w"]), np.asarray(u2["w"]), rtol=1e-3, atol=1e-5
        )
