"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles,
executed in interpret mode (kernel bodies run in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "shape", [(128, 128, 128), (256, 384, 128), (64, 256, 64), (100, 50, 70), (17, 33, 65)]
)
def test_matmul_sweep(shape, dtype):
    M, K, N = shape
    a = jax.random.normal(KEY, (M, K)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N)).astype(dtype)
    out = ops.pallas_matmul(a, b)
    want = ref.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("batch", [(), (3,), (2, 2)])
@pytest.mark.parametrize("sides", ["bi", "left", "right"])
def test_two_sided_rotate_sweep(batch, sides):
    m, n = 48, 32
    x = jax.random.normal(KEY, batch + (m, n))
    U = jnp.linalg.qr(jax.random.normal(KEY, batch + (m, m)))[0] if sides != "right" else None
    V = (
        jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(2), batch + (n, n)))[0]
        if sides != "left"
        else None
    )
    for transpose in (True, False):
        out = ops.two_sided_rotate(x, U, V, transpose=transpose)
        want = ref.two_sided_rotate_ref(x, U, V, transpose)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("shape", [(64, 64), (3, 100, 70), (2, 2, 40, 24)])
def test_fused_adam_scale_sweep(shape):
    g = jax.random.normal(KEY, shape)
    m = jax.random.normal(jax.random.PRNGKey(1), shape)
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), shape)) + 0.1
    s1, v1 = ops.adam_scale(g, m, v, 0.999, 1e-8, 0.5, 0.25)
    s2, v2 = ref.fused_adam_scale_ref(g, m, v, 0.999, 1e-8, 0.5, 0.25)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape,blocks", [((64, 64), (64, 64)),
                                          ((100, 70), (64, 64)),
                                          ((17, 33), (256, 256))])
def test_fused_adam_scale_kernel_parity_interpret(shape, blocks):
    """The Pallas kernel body itself (interpret mode, padded tiles included)
    against the ref.py oracle — guards the kernel's arithmetic, not just the
    ops.py wrapper: the step denominator must be sqrt(v/bc2) + eps exactly."""
    g = jax.random.normal(KEY, shape)
    m = jax.random.normal(jax.random.PRNGKey(1), shape)
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), shape)) + 1e-4
    from repro.kernels.adam_step import fused_adam_scale

    s1, v1 = fused_adam_scale(g, m, v, 0.999, 1e-8, 0.9, 0.1,
                              block_r=blocks[0], block_c=blocks[1],
                              interpret=True)
    s2, v2 = ref.fused_adam_scale_ref(g, m, v, 0.999, 1e-8, 0.9, 0.1)
    # scalars reach the kernel as fp32 (SMEM), so (1 - beta2) differs from
    # the reference's double constant in the last ulp — tolerance covers
    # that, not an algorithmic gap
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("window", [None, 100, 32])
@pytest.mark.parametrize("S,bq,bk", [(256, 64, 64), (128, 128, 32)])
def test_flash_attention_sweep(window, S, bq, bk):
    B, H, dh = 2, 3, 64
    q = jax.random.normal(KEY, (B, H, S, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, dh))
    out = ops.attention(q, k, v, window=window, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-3, atol=2e-4)


def test_flash_attention_bf16():
    B, H, S, dh = 1, 2, 128, 64
    q = jax.random.normal(KEY, (B, H, S, dh)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, dh)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, dh)).astype(jnp.bfloat16)
    out = ops.attention(q, k, v, block_q=64, block_k=64)
    assert out.dtype == jnp.bfloat16  # kernel output stays in q.dtype
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def _qkv(B, H, S, dh, dtype=jnp.float32):
    q = jax.random.normal(KEY, (B, H, S, dh)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, dh)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, dh)).astype(dtype)
    return q, k, v


def _flash_grads(fwd, q, k, v, do):
    return jax.grad(
        lambda q, k, v: jnp.sum(fwd(q, k, v) * do), argnums=(0, 1, 2)
    )(q, k, v)


@pytest.mark.parametrize("window,S,bq,bk", [
    (None, 256, 64, 64),      # causal, aligned blocks
    (32, 256, 64, 64),        # window: in-sequence tiles go fully masked
    (100, 128, 128, 32),      # window wider than bk
    (None, 200, 128, 128),    # S does not divide the blocks: padded rows
    (16, 100, 128, 32),       # padding AND a window together
])
def test_flash_attention_backward_sweep(window, S, bq, bk):
    """Custom-vjp backward (recompute dQ/dK/dV kernels) vs jax.grad of the
    XLA reference, including padded sequence lengths where the cotangents
    for padded rows must vanish from dK/dV."""
    B, H, dh = 1, 2, 32
    q, k, v = _qkv(B, H, S, dh)
    do = jax.random.normal(jax.random.PRNGKey(3), (B, H, S, dh))

    kfwd = lambda q, k, v: ops.attention(q, k, v, window=window,
                                         block_q=bq, block_k=bk)
    rfwd = lambda q, k, v: ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(kfwd(q, k, v)),
                               np.asarray(rfwd(q, k, v)),
                               rtol=2e-3, atol=2e-4)
    got = _flash_grads(kfwd, q, k, v, do)
    want = _flash_grads(rfwd, q, k, v, do)
    for g, w, name in zip(got, want, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-3, atol=1e-4, err_msg=name)


def test_flash_attention_backward_bf16():
    """bf16 grads track the f32 reference grads and keep the input dtype —
    the contract the bf16_compute precision policy relies on."""
    B, H, S, dh = 1, 2, 200, 32  # non-128-multiple S: padded bf16 backward
    q, k, v = _qkv(B, H, S, dh, jnp.bfloat16)
    do = jax.random.normal(jax.random.PRNGKey(3), (B, H, S, dh))

    kfwd = lambda q, k, v: ops.attention(q, k, v, window=24)
    rfwd = lambda q, k, v: ref.flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        window=24,
    )
    got = _flash_grads(kfwd, q, k, v, do.astype(jnp.bfloat16))
    want = _flash_grads(rfwd, q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), do)
    for g, w, name in zip(got, want, ("dq", "dk", "dv")):
        assert g.dtype == jnp.bfloat16, name
        np.testing.assert_allclose(np.asarray(g, np.float32), np.asarray(w),
                                   rtol=6e-2, atol=6e-2, err_msg=name)


def test_flash_fully_masked_rows_zero():
    """Regression: a small window + padding makes whole K-tiles (and, for the
    padded rows past seq_len-2+window, whole ROWS) fully masked. The unguarded
    kernel let exp(s - m) = 1 through for masked entries, turning those rows
    into mean-of-V garbage; they must be exactly zero with an L sentinel."""
    from repro.kernels.flash import NEG_INF, _flash_forward

    S, window, Sp = 100, 8, 128
    q, k, v = _qkv(1, 1, S, 16)
    pad = ((0, 0), (0, Sp - S), (0, 0))
    qf = jnp.pad(q.reshape(1, S, 16), pad)
    kf = jnp.pad(k.reshape(1, S, 16), pad)
    vf = jnp.pad(v.reshape(1, S, 16), pad)
    o, L = _flash_forward((True, window, 128, 128, S, True), qf, kf, vf)

    # rows > seq_len - 2 + window see no valid key at all
    first_dead = S - 1 + window
    assert float(jnp.max(jnp.abs(o[:, first_dead:]))) == 0.0
    # NEG_INF sentinel (f32 rounds -1e30, so compare against a bound)
    assert bool(jnp.all(L[:, first_dead:] <= -1e29))
    # the row just before still attends to key seq_len-1: finite and nonzero
    assert float(L[0, first_dead - 1]) > -1e29
    assert float(jnp.max(jnp.abs(o[:, first_dead - 1]))) > 0.0
    # in-sequence rows agree with the reference despite the dead tiles
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o[:, :S].reshape(1, 1, S, 16)),
                               np.asarray(want), rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("window", [None, 24])
def test_gqa_train_kernel_parity(window):
    """gqa_train(use_kernels=True) == the dense path, values and grads, with
    grouped KV heads (kv_groups > 1) and a non-block-multiple sequence."""
    from repro.configs.base import AttentionConfig
    from repro.models.attention import gqa_train, init_attention

    cfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16,
                          window=window)
    d_model, B, S = 32, 2, 48
    params = init_attention(KEY, d_model, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(7), (B, S, d_model))

    out_k = gqa_train(params, x, cfg, use_kernels=True)
    out_d = gqa_train(params, x, cfg, use_kernels=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_d),
                               rtol=2e-3, atol=2e-4)

    def loss(fn_kernels):
        return lambda p, x: jnp.sum(
            gqa_train(p, x, cfg, use_kernels=fn_kernels) ** 2
        )

    gk = jax.grad(loss(True), argnums=(0, 1))(params, x)
    gd = jax.grad(loss(False), argnums=(0, 1))(params, x)
    flat_k, _ = jax.tree_util.tree_flatten_with_path(gk)
    flat_d, _ = jax.tree_util.tree_flatten_with_path(gd)
    for (path, a), (_, b) in zip(flat_k, flat_d):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4,
                                   err_msg=jax.tree_util.keystr(path))


def test_kernel_backed_rotation_matches_reference_path():
    """Full basis-rotation step with kernels == pure-jnp path, on a
    well-conditioned state (v warmed so the step isn't 0/0-sensitive)."""
    from repro.core import basis_rotation_adam
    from repro.optim import constant_schedule

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (64, 48))}
    sched = constant_schedule(1e-2)
    b_ref = basis_rotation_adam(sched, freq=2, use_kernels=False)
    b_ker = basis_rotation_adam(sched, freq=2, use_kernels=True)
    s1, s2 = b_ref.init(params), b_ker.init(params)
    # warm v so denominators are well-conditioned
    for leaf in (s1["leaves"][0], s2["leaves"][0]):
        leaf["v"] = jnp.ones_like(leaf["v"])
    for t in range(4):
        g = {"w": jax.random.normal(jax.random.PRNGKey(10 + t), (64, 48))}
        u1, s1 = b_ref.update(g, s1, params, jnp.int32(t))
        u2, s2 = b_ker.update(g, s2, params, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(u1["w"]), np.asarray(u2["w"]), rtol=1e-3, atol=1e-5
        )
