"""Production-sharding audit over the FULL assigned configs (shapes only —
nothing is allocated): every parameter and optimizer-state leaf must receive
a PartitionSpec whose sharded dims divide the mesh axes, for both production
meshes. This is the static half of the dry-run guarantee and runs in CI
without the 512-device topology."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import OptimizerConfig
from repro.models import init_model
from repro.optim.factory import build_optimizer
from repro.sharding.rules import opt_state_pspecs, params_pspecs

MESHES = {
    "single_pod": {"data": 16, "model": 16},
    "multi_pod": {"pod": 2, "data": 16, "model": 16},
}


def _check_divisibility(shapes_tree, specs_tree, mesh, label):
    flat_s = jax.tree_util.tree_leaves(shapes_tree)
    flat_p = jax.tree_util.tree_leaves(specs_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p), label
    n_sharded = 0
    for aval, spec in zip(flat_s, flat_p):
        assert isinstance(spec, P), label
        assert len(spec) <= len(aval.shape), (label, aval.shape, spec)
        for dim, axes in zip(aval.shape, spec):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            total = 1
            for a in axes:
                total *= mesh[a]
            assert dim % total == 0, (label, aval.shape, spec)
            n_sharded += 1
    return n_sharded


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_and_state_specs(arch, mesh_name):
    mesh = MESHES[mesh_name]
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    specs = params_pspecs(shapes, mesh)
    n = _check_divisibility(shapes, specs, mesh, f"{arch}/params")
    assert n > 0, f"{arch}: nothing sharded at all"

    opt = build_optimizer(
        OptimizerConfig(name="basis_rotation", rotation_source="1st",
                        rotation_geometry="unilateral", total_steps=10),
        shapes, cfg, num_stages=1, apply_delay=False,
    )
    st = jax.eval_shape(opt.init, shapes)
    st_specs = opt_state_pspecs(st, shapes, mesh)
    _check_divisibility(st, st_specs, mesh, f"{arch}/opt_state")
