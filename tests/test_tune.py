"""Kernel block autotuner (`repro.tune`): persistent-cache semantics
(round-trip, stale-schema keys ignored, corrupt file tolerated, atomic
merge), the analytical cost model's platform-dependent choices, and the
trace-time consumption path through `kernels/flash.py::_plan`."""
import json

import pytest

from repro import tune
from repro.tune import cache as tcache
from repro.tune.cost_model import (
    best_elementwise_plan,
    best_flash_plan,
    best_matmul_plan,
    candidate_blocks,
)


@pytest.fixture(autouse=True)
def _fresh_memo():
    # the lookup memo is process-global; every test starts and ends clean
    tcache.clear_memo()
    yield
    tcache.clear_memo()


# ---------------------------------------------------------------------------
# cache: round-trip, tolerance, atomic merge
# ---------------------------------------------------------------------------


def test_cache_round_trip(tmp_path):
    p = str(tmp_path / "tune.json")
    plan = {"block_q": 256, "block_k": 128, "backend": "cost_model"}
    tcache.save_entries({tcache.make_key("flash", (256, 16), "float32",
                                         "cpu"): plan}, p)
    assert tcache.lookup("flash", (256, 16), "float32", "cpu", p) == plan
    # different shape / dtype / platform / kernel: all misses
    assert tcache.lookup("flash", (512, 16), "float32", "cpu", p) is None
    assert tcache.lookup("flash", (256, 16), "bfloat16", "cpu", p) is None
    assert tcache.lookup("flash", (256, 16), "float32", "tpu", p) is None
    assert tcache.lookup("matmul", (256, 16), "float32", "cpu", p) is None


def test_cache_merge_preserves_other_keys(tmp_path):
    p = str(tmp_path / "tune.json")
    k1 = tcache.make_key("flash", (128, 16), "float32", "cpu")
    k2 = tcache.make_key("matmul", (64, 64, 64), "float32", "cpu")
    tcache.save_entries({k1: {"block_q": 128}}, p)
    tcache.save_entries({k2: {"block_m": 64}}, p)
    got = tcache.load_cache(p)
    assert set(got) == {k1, k2}
    # last writer wins per key
    tcache.save_entries({k1: {"block_q": 64}}, p)
    assert tcache.load_cache(p)[k1] == {"block_q": 64}


def test_cache_missing_and_corrupt_files_are_empty(tmp_path):
    assert tcache.load_cache(str(tmp_path / "nope.json")) == {}
    bad = tmp_path / "bad.json"
    bad.write_text("{truncated")
    assert tcache.load_cache(str(bad)) == {}
    assert tcache.lookup("flash", (256, 16), "float32", "cpu",
                         str(bad)) is None
    # a corrupt file is also recoverable: the next write replaces it
    tcache.save_entries({"flash|8|float32|cpu": {"block_q": 8}}, str(bad))
    assert tcache.load_cache(str(bad)) == {"flash|8|float32|cpu":
                                           {"block_q": 8}}


def test_cache_foreign_schema_and_junk_entries_ignored(tmp_path):
    p = tmp_path / "old.json"
    p.write_text(json.dumps({
        "schema": "repro-tune/v0",
        "entries": {"flash|256x16|float32|cpu": {"block_q": 999}},
    }))
    # stale layout: every key under it is untrusted
    assert tcache.load_cache(str(p)) == {}
    # current schema but junk values: non-dict entries dropped on read
    p.write_text(json.dumps({
        "schema": tcache.SCHEMA,
        "entries": {"good|1|float32|cpu": {"block_q": 8}, "junk": 17},
    }))
    assert tcache.load_cache(str(p)) == {"good|1|float32|cpu": {"block_q": 8}}


def test_save_entries_invalidates_memo(tmp_path):
    p = str(tmp_path / "tune.json")
    key = tcache.make_key("flash", (64, 16), "float32", "cpu")
    assert tcache.lookup("flash", (64, 16), "float32", "cpu", p) is None
    tcache.save_entries({key: {"block_q": 64, "block_k": 64}}, p)
    # without clear_memo inside save_entries this would still be None
    assert tcache.lookup("flash", (64, 16), "float32", "cpu",
                         p)["block_q"] == 64


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_candidate_blocks_powers_of_two():
    assert candidate_blocks(256) == [8, 16, 32, 64, 128, 256]
    assert candidate_blocks(100) == [8, 16, 32, 64, 128]  # next pow2 cap
    assert candidate_blocks(4) == [8]  # f32 min sublane floor


def test_cost_model_interpret_prefers_full_tiles():
    # off-TPU the per-grid-step interpreter overhead dominates: the model
    # must collapse to one full-operand tile (fewest grid steps)
    plan = best_flash_plan(256, 16, batch_heads=2, dtype_bytes=4,
                           causal=True, platform="cpu")
    assert (plan["block_q"], plan["block_k"]) == (256, 256)
    assert plan["backend"] == "cost_model"
    mm = best_matmul_plan(256, 256, 256, dtype_bytes=4, platform="cpu")
    assert (mm["block_m"], mm["block_n"], mm["block_k"]) == (256, 256, 256)
    el = best_elementwise_plan(1024, 1024, dtype_bytes=4, platform="cpu")
    assert (el["block_r"], el["block_c"]) == (1024, 1024)


def test_cost_model_tpu_respects_vmem_budget():
    # a long sequence cannot take the full-operand tile on TPU: the plan
    # must fit the VMEM budget, so block_q * block_k stays bounded
    plan = best_flash_plan(8192, 128, batch_heads=8, dtype_bytes=4,
                           causal=True, platform="tpu")
    from repro.tune.cost_model import VMEM_BUDGET, VMEM_BYTES, flash_vmem_bytes

    assert flash_vmem_bytes(plan["block_q"], plan["block_k"], 128, 4) \
        <= VMEM_BUDGET * VMEM_BYTES
    assert plan["cost_s"] > 0


# ---------------------------------------------------------------------------
# tune -> cache -> kernels/_plan consumption
# ---------------------------------------------------------------------------


def test_tune_flash_persists_and_kernel_plan_reads(tmp_path):
    p = str(tmp_path / "tune.json")
    plan = tune.tune_flash(256, 16, batch_heads=2, path=p)
    got = tune.kernel_plan("flash", (256, 16), "float32", path=p)
    assert got is not None
    assert (got["block_q"], got["block_k"]) == (plan["block_q"],
                                                plan["block_k"])
    # write=False must not touch the cache (benchmarks rely on this)
    tune.tune_flash(512, 64, path=p, write=False)
    assert tune.kernel_plan("flash", (512, 64), "float32", path=p) is None


def test_flash_plan_consults_cache(tmp_path, monkeypatch):
    from repro.kernels.flash import _plan

    p = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", p)
    tcache.save_entries({
        tcache.make_key("flash", (256, 16), "float32",
                        tune.platform_name()): {"block_q": 32, "block_k": 64},
    }, p)
    bq, bk, s = _plan(256, dh=16, dtype_name="float32", interpret=True)
    assert (bq, bk) == (32, 64)
    # explicit caller blocks always win over the cache
    bq, bk, _ = _plan(256, 16, 16, dh=16, dtype_name="float32",
                      interpret=True)
    assert (bq, bk) == (16, 16)
    # a miss falls back to the static default (full tile in interpret mode)
    bq, bk, _ = _plan(128, dh=64, dtype_name="float32", interpret=True)
    assert (bq, bk) == (128, 128)


def test_tuned_blocks_numerics_match_defaults():
    """A tuned plan changes speed, never values: attention with cached
    blocks agrees with the hardcoded-default blocks."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 128, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 16))
    base = ops.attention(q, k, v, window=32)
    tuned = ops.attention(q, k, v, window=32, block_q=32, block_k=64)
    assert float(jnp.max(jnp.abs(base - tuned))) < 1e-5
