"""Fig. 5 (+12/13): robustness to pipeline depth P for the same model.

Runs every method at P in {1, 8} (quick) or {1, 4, 8, 16} (full) on the
reduced LM and reports final losses + slowdown (iterations to the target loss
at max P relative to P=1)."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import slowdown, tail, train_curve

METHODS = ["adam", "pipedream_lr", "nesterov", "basis_rotation"]


def run(quick: bool = True):
    stages = [1, 8] if quick else [1, 4, 8, 16]
    steps = 150 if quick else 400
    rows = []
    ref_curves = {}
    for m in METHODS:
        curves = {}
        for p in stages:
            out = train_curve(m, stages=p, steps=steps)
            curves[p] = out
        ref_curves[m] = curves
        target = tail(curves[1]["losses"]) * 1.07 + 0.02
        sd = slowdown(curves[stages[-1]]["losses"], curves[1]["losses"], target)
        rows.append({
            "name": f"fig5/{m}",
            "us_per_call": curves[stages[-1]]["us_per_step"],
            "derived": ";".join(
                [f"final_P{p}={tail(curves[p]['losses']):.3f}" for p in stages]
            ) + f";slowdown_P{stages[-1]}={sd:.2f}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
