"""Fig. 5 (+12/13): robustness to pipeline depth P for the same model.

Runs every method at P in {1, 8} (quick) or {1, 4, 8, 16} (full) on the
reduced LM and reports final losses + slowdown (iterations to the target loss
at max P relative to P=1).

``--backend spmd`` runs the same sweep on the shard_map pipeline runtime
(`SpmdEngine` in a subprocess with forced host devices, staleness imposed by
the per-stage delay FIFO) and reports the sim final next to the SPMD final —
the engine-driven cross-validation of the convergence claims.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import slowdown, spmd_train_curves, tail, train_curve

METHODS = ["adam", "pipedream_lr", "nesterov", "basis_rotation"]


def run(quick: bool = True):
    stages = [1, 8] if quick else [1, 4, 8, 16]
    steps = 150 if quick else 400
    rows = []
    ref_curves = {}
    for m in METHODS:
        curves = {}
        for p in stages:
            out = train_curve(m, stages=p, steps=steps)
            curves[p] = out
        ref_curves[m] = curves
        target = tail(curves[1]["losses"]) * 1.07 + 0.02
        sd = slowdown(curves[stages[-1]]["losses"], curves[1]["losses"], target)
        rows.append({
            "name": f"fig5/{m}",
            "us_per_call": curves[stages[-1]]["us_per_step"],
            "derived": ";".join(
                [f"final_P{p}={tail(curves[p]['losses']):.3f}" for p in stages]
            ) + f";slowdown_P{stages[-1]}={sd:.2f}",
        })
    return rows


def run_spmd(quick: bool = True, smoke: bool = False):
    """The same sweep on `SpmdEngine`, each point cross-checked vs the sim."""
    stages = [1, 4] if (quick or smoke) else [1, 4, 8]
    steps = 20 if smoke else (100 if quick else 300)
    runs = [{"name": m, "stages": p, "steps": steps}
            for m in METHODS for p in stages]
    spmd = spmd_train_curves(runs)
    rows = []
    for i, m in enumerate(METHODS):
        derived = []
        us = 0.0
        for j, p in enumerate(stages):
            got = spmd[i * len(stages) + j]
            sim = train_curve(m, stages=p, steps=steps)
            us = got["us_per_step"]
            derived.append(
                f"final_P{p}={tail(got['losses']):.3f}"
                f";sim_P{p}={tail(sim['losses']):.3f}"
            )
        rows.append({
            "name": f"fig5/spmd_{m}",
            "us_per_call": us,
            "derived": ";".join(derived),
        })
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="sim", choices=["sim", "spmd"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep / few steps (CI)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.backend == "spmd":
        emit(run_spmd(quick=not args.full, smoke=args.smoke))
    else:
        emit(run(quick=not args.full))
