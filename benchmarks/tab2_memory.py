"""Table 2: memory overhead of the four estimation strategies on
Llama-3-8B-shaped matrices (attention 4096x4096, MLP 4096x14336), FP32.

Computed EXACTLY from the optimizer's real state pytrees (not formulas):
we init the basis-rotation state for one matrix of each shape and count
state bytes beyond plain Adam's m/v."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import basis_rotation_adam
from repro.optim import constant_schedule

SHAPES = {"attn": (4096, 4096), "mlp": (4096, 14336)}


def _state_bytes(shape, source, geometry):
    params = {"w": jax.ShapeDtypeStruct(shape, jnp.float32)}
    opt = basis_rotation_adam(constant_schedule(1.0), source=source, geometry=geometry)
    st = jax.eval_shape(opt.init, params)
    leaf = st["leaves"][0]
    extra = 0
    for k, v in leaf.items():
        if k in ("m", "v"):
            continue
        extra += v.size * 4
    return extra


def run(quick: bool = True):
    rows = []
    for source in ("2nd", "1st"):
        for geometry in ("bilateral", "unilateral"):
            attn = _state_bytes(SHAPES["attn"], source, geometry) / 1e9
            mlp = _state_bytes(SHAPES["mlp"], source, geometry) / 1e9
            rows.append({
                "name": f"tab2/{source}_{geometry[:3]}",
                "us_per_call": 0.0,
                "derived": f"attn_gb={attn:.2f};mlp_gb={mlp:.2f}",
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
