"""Table 2: memory overhead of the four estimation strategies on
Llama-3-8B-shaped matrices (attention 4096x4096, MLP 4096x14336), FP32.

Computed EXACTLY from the optimizer's real state pytrees (not formulas):
we init the basis-rotation state for one matrix of each shape and count
state bytes beyond plain Adam's m/v.

Also reports the SPMD runtime's per-stage live activation buffers under the
two tick schedules — fill-drain's O(M) staging vs 1F1B's O(K) stash — at the
paper's pipeline shape, from the schedules' own memory model."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import basis_rotation_adam
from repro.engine import schedule_activation_bytes
from repro.optim import constant_schedule

SHAPES = {"attn": (4096, 4096), "mlp": (4096, 14336)}


def _state_bytes(shape, source, geometry):
    params = {"w": jax.ShapeDtypeStruct(shape, jnp.float32)}
    opt = basis_rotation_adam(constant_schedule(1.0), source=source, geometry=geometry)
    st = jax.eval_shape(opt.init, params)
    leaf = st["leaves"][0]
    extra = 0
    for k, v in leaf.items():
        if k in ("m", "v"):
            continue
        extra += v.size * 4
    return extra


def _schedule_rows(stages: int = 16, microbatches: int = 64,
                   microbatch_size: int = 8, seq_len: int = 512):
    """Per-stage activation-buffer bytes for each pipeline schedule at the
    paper's production pipeline shape (16 stages, 64 microbatches)."""
    cfg = get_config("paper_95m")
    rows = []
    for sched in ("fill_drain", "1f1b"):
        gb = schedule_activation_bytes(
            cfg, stages, microbatches, microbatch_size, seq_len, schedule=sched
        ) / 1e9
        rows.append({
            "name": f"tab2/pipe_act_{sched}",
            "us_per_call": 0.0,
            "derived": f"K={stages};M={microbatches};per_stage_gb={gb:.3f}",
        })
    return rows


def run(quick: bool = True):
    rows = []
    for source in ("2nd", "1st"):
        for geometry in ("bilateral", "unilateral"):
            attn = _state_bytes(SHAPES["attn"], source, geometry) / 1e9
            mlp = _state_bytes(SHAPES["mlp"], source, geometry) / 1e9
            rows.append({
                "name": f"tab2/{source}_{geometry[:3]}",
                "us_per_call": 0.0,
                "derived": f"attn_gb={attn:.2f};mlp_gb={mlp:.2f}",
            })
    rows.extend(_schedule_rows())
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
