"""Fig. 3: basis alignment vs. delay sensitivity on a quadratic.

min_w 1/2 w^T H w with (a) diagonal H (aligned) and (b) rotated H
(misaligned), optimised by AdaSGD and Adam with and without delay tau=2.
Derived metric: iterations to reach the target loss — the paper's point is
that delay barely hurts Adam when aligned but badly hurts it when misaligned.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.optim import adam, adasgd, constant_schedule
from repro.optim.base import apply_updates
from repro.pipeline.delay import delayed_optimizer

D = 16
TARGET = 15.0


def _problem(misaligned: bool):
    key = jax.random.PRNGKey(0)
    diag = jnp.concatenate([jnp.asarray([40.0]), jnp.linspace(3.0, 0.5, D - 1)])
    if misaligned:
        Q = jnp.linalg.qr(jax.random.normal(key, (D, D)))[0]
        H = Q @ jnp.diag(diag) @ Q.T
    else:
        H = jnp.diag(diag)
    w0 = jnp.full((D,), 4.0)
    return H, w0


def _run(opt_name: str, misaligned: bool, tau: int, max_iters: int = 3000):
    H, w = _problem(misaligned)
    # calibrated to the paper's regime (beta1=0, small beta2): delay is
    # harmless when aligned, ~3x slower when misaligned
    sched = constant_schedule(0.3)
    base = adam(sched, beta1=0.0, beta2=0.5) if opt_name == "adam" else adasgd(
        sched, beta1=0.0, beta2=0.5
    )
    opt = delayed_optimizer(base, [tau]) if tau > 0 else base
    params = {"w": w}
    state = opt.init(params)
    for t in range(max_iters):
        loss = 0.5 * params["w"] @ H @ params["w"]
        if float(loss) <= TARGET:
            return t
        g = {"w": H @ params["w"]}
        u, state = opt.update(g, state, params, jnp.int32(t))
        params = apply_updates(params, u)
    return max_iters


def run(quick: bool = True):
    rows = []
    for opt_name in ("adam", "adasgd"):
        for misaligned in (False, True):
            t0 = time.perf_counter()
            it0 = _run(opt_name, misaligned, tau=0)
            it2 = _run(opt_name, misaligned, tau=2)
            dt = (time.perf_counter() - t0) * 1e6
            align = "misaligned" if misaligned else "aligned"
            rows.append({
                "name": f"fig3/{opt_name}/{align}",
                "us_per_call": dt,
                "derived": f"iters_nodelay={it0};iters_delay2={it2};"
                           f"ratio={it2 / max(it0, 1):.2f}",
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
