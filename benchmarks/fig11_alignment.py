"""Fig. 11 + Hessian (1,1)-norm: empirical validation of basis alignment.

Tracks parameter-update oscillation along the dominant Hessian eigenvector
(estimated by power iteration on HVPs) with and without basis rotation, and
estimates the normalized Hessian (1,1)-norm via random Cauchy quadratic forms
(Xie et al. 2025). Rotation should damp dominant-direction oscillation and
shrink the norm."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_MODEL
from repro.configs.base import OptimizerConfig
from repro.core.theory import estimate_norm_11
from repro.data import batches
from repro.models import init_model
from repro.models.model import loss_fn
from repro.optim.base import apply_updates, make_schedule
from repro.optim.factory import build_optimizer

CFG = BENCH_MODEL.replace(num_layers=4)


def _flatten(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([x.reshape(-1) for x in leaves])


def _unflatten_like(vec, tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, i = [], 0
    for x in leaves:
        out.append(vec[i : i + x.size].reshape(x.shape).astype(x.dtype))
        i += x.size
    return jax.tree_util.tree_unflatten(treedef, out)


def _dominant_eigvec(params, batch, iters=8):
    def scalar_loss(p):
        return loss_fn(p, CFG, batch)[0]

    dim = _flatten(params).shape[0]
    v = jax.random.normal(jax.random.PRNGKey(7), (dim,))
    v = v / jnp.linalg.norm(v)
    for _ in range(iters):
        tangent = _unflatten_like(v, params)
        _, hv = jax.jvp(jax.grad(scalar_loss), (params,), (tangent,))
        hv = _flatten(hv)
        v = hv / (jnp.linalg.norm(hv) + 1e-12)
    return v


def _oscillation(name, steps, v_dom):
    params = init_model(jax.random.PRNGKey(0), CFG)
    ocfg = OptimizerConfig(name=name, learning_rate=3e-3, total_steps=steps,
                           rotation_freq=5)
    opt = build_optimizer(ocfg, params, CFG, num_stages=4)
    state = opt.init(params)
    data = batches(CFG, 8, 32, seed=0)
    projs = []
    prev = _flatten(params)
    for t in range(steps):
        batch = next(data)
        (_, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, CFG, batch)
        u, state = opt.update(grads, state, params, jnp.int32(t))
        params = apply_updates(params, u)
        cur = _flatten(params)
        projs.append(float((cur - prev) @ v_dom))
        prev = cur
    # oscillation = sign-flip rate x mean |proj|
    p = np.asarray(projs[10:])
    flips = np.mean(np.sign(p[1:]) != np.sign(p[:-1]))
    return float(flips), float(np.mean(np.abs(p)))


def run(quick: bool = True):
    steps = 60 if quick else 200
    batch = next(batches(CFG, 8, 32, seed=1))
    params = init_model(jax.random.PRNGKey(0), CFG)
    v_dom = _dominant_eigvec(params, batch)
    rows = []
    for m in ("adam", "basis_rotation"):
        flips, mag = _oscillation(m, steps, v_dom)
        rows.append({
            "name": f"fig11/{m}",
            "us_per_call": 0.0,
            "derived": f"signflip_rate={flips:.2f};mean_abs_proj={mag:.2e}",
        })

    # Hessian (1,1)-norm estimate at init (Cauchy quadratic forms)
    def scalar_loss(p):
        return loss_fn(p, CFG, batch)[0]

    dim = _flatten(params).shape[0]

    def hvp(v):
        t = _unflatten_like(v, params)
        _, hv = jax.jvp(jax.grad(scalar_loss), (params,), (t,))
        return _flatten(hv)

    est = estimate_norm_11(hvp, dim, jax.random.PRNGKey(3), num_samples=8 if quick else 64)
    rows.append({
        "name": "fig11/h11_norm_per_param",
        "us_per_call": 0.0,
        "derived": f"estimate={float(est) / dim:.4e}",
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
