"""Shared benchmark helpers: tiny-model training runs with exact delay
simulation, timing, and iterations-to-target-loss measurement.

All benchmarks run REDUCED-scale versions of the paper's experiments on CPU
with fixed seeds; each module maps 1:1 to a paper table/figure and returns
rows of (name, us_per_call, derived-metric).
"""
from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional, Sequence

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    MoEConfig,
    ModelConfig,
    OptimizerConfig,
)
from repro.data import batches
from repro.engine import LoopConfig, SimEngine, run_loop
from repro.models import init_model
from repro.optim.factory import build_optimizer

BENCH_MODEL = ModelConfig(
    name="bench_lm",
    num_layers=8,
    d_model=64,
    d_ff=256,
    vocab_size=128,
    max_seq_len=64,
    attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    pattern=(BlockSpec("attn", "dense"),),
    norm="layernorm",
    mlp_act="gelu",
    learnable_pos_emb=True,
    scan_layers=False,
)

BENCH_MOE = BENCH_MODEL.replace(
    name="bench_moe",
    num_layers=4,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    pattern=(BlockSpec("attn", "moe"),),
)


def train_curve(
    name: str,
    stages: int,
    steps: int,
    cfg: ModelConfig = BENCH_MODEL,
    lr: float = 3e-3,
    seed: int = 0,
    batch: int = 8,
    seq: int = 32,
    data_delay: int = 0,
    **okw,
) -> Dict:
    """Run one simulated-async training; returns losses + per-step wall time.

    ``data_delay`` adds the uniform data-axis staleness of a deferred
    cross-replica reduction on top of each leaf's pipeline delay (the sim
    analogue of the SPMD engine's ``data_async`` FIFO)."""
    ocfg = OptimizerConfig(name=name, learning_rate=lr, total_steps=steps,
                           rotation_freq=okw.pop("rotation_freq", 5), **okw)
    params = init_model(jax.random.PRNGKey(seed), cfg)
    opt = build_optimizer(ocfg, params, cfg, num_stages=stages,
                          data_delay=data_delay)
    engine = SimEngine(cfg, opt)
    state = engine.init_state(params=params)
    t0 = time.perf_counter()
    _, losses = run_loop(engine, batches(cfg, batch, seq, seed=seed),
                         LoopConfig(steps=steps), state=state)
    dt = time.perf_counter() - t0
    return {"losses": losses, "us_per_step": 1e6 * dt / steps}


SPMD_CURVES_SCRIPT = r"""
import sys
sys.path.insert(0, "src")
from repro.launch.devices import ensure_host_devices
ensure_host_devices(%(devices)d)
import json, time
import jax
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec, OptimizerConfig
from repro.data import batches
from repro.engine import LoopConfig, SpmdEngine, run_loop
from repro.launch.topology import Topology
from repro.models import init_model

runs = %(runs)s
out = []
for r in runs:
    cfg = ModelConfig(
        name="bench_lm", num_layers=r["num_layers"], d_model=64, d_ff=256,
        vocab_size=128, max_seq_len=64,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),), norm="layernorm", mlp_act="gelu",
        learnable_pos_emb=True, scan_layers=False)
    K = r["stages"]
    ocfg = OptimizerConfig(name=r["name"], learning_rate=r["lr"],
                           total_steps=r["steps"], rotation_freq=r["rotation_freq"],
                           **r["okw"])
    engine = SpmdEngine(cfg, ocfg, num_stages=K, num_microbatches=K,
                        topology=Topology(stages=K, data=r["data_par"]),
                        schedule=r["schedule"], use_kernels=r["use_kernels"],
                        precision=r["precision"],
                        data_async=r["data_async"], data_delay=r["data_delay"])
    params = init_model(jax.random.PRNGKey(r["seed"]), cfg)
    state = engine.init_state(params=params)
    data = batches(cfg, r["batch"], r["seq"], seed=r["seed"])
    t0 = time.perf_counter()
    state, losses = run_loop(engine, data, LoopConfig(steps=r["steps"]), state=state)
    dt = time.perf_counter() - t0
    out.append({"losses": losses, "us_per_step": 1e6 * dt / r["steps"]})
print(json.dumps(out))
"""


def spmd_train_curves(runs: List[Dict]) -> List[Dict]:
    """Run `train_curve`-style async trainings on the SPMD backend.

    Each run dict: {name, stages, steps, num_layers, lr, seed, batch, seq,
    rotation_freq, okw, data_par, data_async, data_delay}. All runs execute
    in ONE subprocess with ``max(stages * data_par)`` forced host devices
    (smaller topologies use a device prefix), so the engine-driven fig5/fig6
    sweeps cross-validate the sim convergence claims on the real shard_map
    runtime without a process per point. ``data_par > 1`` shards the batch
    over replicas; ``data_async``/``data_delay`` route the cross-replica
    gradient reduction through the engine's deferred FIFO. Staleness matches the sim path: the per-stage delay FIFO on the
    stage-stacked layout == the simulator's per-leaf FIFO.
    """
    import json
    import os
    import subprocess

    defaults = {"num_layers": 8, "lr": 3e-3, "seed": 0, "batch": 8, "seq": 32,
                "rotation_freq": 5, "okw": {}, "schedule": "fill_drain",
                "use_kernels": False, "precision": "f32", "data_par": 1,
                "data_async": False, "data_delay": 0}
    runs = [{**defaults, **r} for r in runs]
    script = SPMD_CURVES_SCRIPT % {
        "devices": max(r["stages"] * r["data_par"] for r in runs),
        "runs": repr(runs),
    }
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=3600,
    )
    if out.returncode != 0:
        raise RuntimeError(f"spmd curve subprocess failed: {out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def iters_to_loss(losses: Sequence[float], target: float) -> Optional[int]:
    run_min = float("inf")
    for i, l in enumerate(losses):
        run_min = min(run_min, l)
        if run_min <= target:
            return i + 1
    return None


def slowdown(losses_delayed, losses_ref, target: float) -> float:
    a = iters_to_loss(losses_delayed, target)
    b = iters_to_loss(losses_ref, target)
    if b is None or b == 0:
        return float("nan")
    if a is None:
        return float("inf")  # never reached the target: the paper's "diverged"
    return a / b


def tail(losses: Sequence[float], k: int = 10) -> float:
    return sum(losses[-k:]) / min(k, len(losses))


def emit(rows: List[Dict]) -> None:
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.1f},{r.get('derived', '')}")
