"""Shared benchmark helpers: tiny-model training runs with exact delay
simulation, timing, and iterations-to-target-loss measurement.

All benchmarks run REDUCED-scale versions of the paper's experiments on CPU
with fixed seeds; each module maps 1:1 to a paper table/figure and returns
rows of (name, us_per_call, derived-metric).
"""
from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional, Sequence

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AttentionConfig,
    BlockSpec,
    MoEConfig,
    ModelConfig,
    OptimizerConfig,
)
from repro.data import batches
from repro.engine import LoopConfig, SimEngine, run_loop
from repro.models import init_model
from repro.optim.factory import build_optimizer

BENCH_MODEL = ModelConfig(
    name="bench_lm",
    num_layers=8,
    d_model=64,
    d_ff=256,
    vocab_size=128,
    max_seq_len=64,
    attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    pattern=(BlockSpec("attn", "dense"),),
    norm="layernorm",
    mlp_act="gelu",
    learnable_pos_emb=True,
    scan_layers=False,
)

BENCH_MOE = BENCH_MODEL.replace(
    name="bench_moe",
    num_layers=4,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    pattern=(BlockSpec("attn", "moe"),),
)


def train_curve(
    name: str,
    stages: int,
    steps: int,
    cfg: ModelConfig = BENCH_MODEL,
    lr: float = 3e-3,
    seed: int = 0,
    batch: int = 8,
    seq: int = 32,
    **okw,
) -> Dict:
    """Run one simulated-async training; returns losses + per-step wall time."""
    ocfg = OptimizerConfig(name=name, learning_rate=lr, total_steps=steps,
                           rotation_freq=okw.pop("rotation_freq", 5), **okw)
    params = init_model(jax.random.PRNGKey(seed), cfg)
    opt = build_optimizer(ocfg, params, cfg, num_stages=stages)
    engine = SimEngine(cfg, opt)
    state = engine.init_state(params=params)
    t0 = time.perf_counter()
    _, losses = run_loop(engine, batches(cfg, batch, seq, seed=seed),
                         LoopConfig(steps=steps), state=state)
    dt = time.perf_counter() - t0
    return {"losses": losses, "us_per_step": 1e6 * dt / steps}


def iters_to_loss(losses: Sequence[float], target: float) -> Optional[int]:
    run_min = float("inf")
    for i, l in enumerate(losses):
        run_min = min(run_min, l)
        if run_min <= target:
            return i + 1
    return None


def slowdown(losses_delayed, losses_ref, target: float) -> float:
    a = iters_to_loss(losses_delayed, target)
    b = iters_to_loss(losses_ref, target)
    if b is None or b == 0:
        return float("nan")
    if a is None:
        return float("inf")  # never reached the target: the paper's "diverged"
    return a / b


def tail(losses: Sequence[float], k: int = 10) -> float:
    return sum(losses[-k:]) / min(k, len(losses))


def emit(rows: List[Dict]) -> None:
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.1f},{r.get('derived', '')}")
