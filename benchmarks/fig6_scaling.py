"""Fig. 6 (+14): scaling the model by adding blocks, one block per stage.

Baselines invert the scaling law under async pipelining (bigger model =>
HIGHER loss); basis rotation restores it. Derived metric: final loss at each
(blocks == stages) size."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import BENCH_MODEL, tail, train_curve


def run(quick: bool = True):
    sizes = [4, 8] if quick else [4, 8, 16, 32]
    steps = 150 if quick else 400
    rows = []
    for m in ("adam", "basis_rotation"):
        finals = {}
        us = 0.0
        for L in sizes:
            cfg = BENCH_MODEL.replace(num_layers=L)
            out = train_curve(m, stages=L, steps=steps, cfg=cfg)
            finals[L] = tail(out["losses"])
            us = out["us_per_step"]
        trend = finals[sizes[-1]] - finals[sizes[0]]  # <0 => scaling works
        rows.append({
            "name": f"fig6/{m}",
            "us_per_call": us,
            "derived": ";".join(f"final_L{k}={v:.3f}" for k, v in finals.items())
            + f";scaling_delta={trend:+.3f}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
