"""Fig. 6 (+14): scaling the model by adding blocks, one block per stage.

Baselines invert the scaling law under async pipelining (bigger model =>
HIGHER loss); basis rotation restores it. Derived metric: final loss at each
(blocks == stages) size.

``--backend spmd`` runs the sweep on the shard_map pipeline runtime with the
per-stage delay FIFO, reporting the sim final beside each SPMD final — the
scaling-trend cross-validation on the real engine.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import BENCH_MODEL, spmd_train_curves, tail, train_curve

SPMD_METHODS = ("adam", "basis_rotation")


def run(quick: bool = True):
    sizes = [4, 8] if quick else [4, 8, 16, 32]
    steps = 150 if quick else 400
    rows = []
    for m in ("adam", "basis_rotation"):
        finals = {}
        us = 0.0
        for L in sizes:
            cfg = BENCH_MODEL.replace(num_layers=L)
            out = train_curve(m, stages=L, steps=steps, cfg=cfg)
            finals[L] = tail(out["losses"])
            us = out["us_per_step"]
        trend = finals[sizes[-1]] - finals[sizes[0]]  # <0 => scaling works
        rows.append({
            "name": f"fig6/{m}",
            "us_per_call": us,
            "derived": ";".join(f"final_L{k}={v:.3f}" for k, v in finals.items())
            + f";scaling_delta={trend:+.3f}",
        })
    return rows


def run_spmd(quick: bool = True, smoke: bool = False):
    sizes = [4, 8] if (quick or smoke) else [4, 8, 16]
    steps = 20 if smoke else (100 if quick else 300)
    # M = stages, so the global batch must reach the microbatch count
    runs = [{"name": m, "stages": L, "num_layers": L, "steps": steps,
             "batch": max(8, L)}
            for m in SPMD_METHODS for L in sizes]
    spmd = spmd_train_curves(runs)
    rows = []
    for i, m in enumerate(SPMD_METHODS):
        finals, sim_finals = {}, {}
        us = 0.0
        for j, L in enumerate(sizes):
            got = spmd[i * len(sizes) + j]
            finals[L] = tail(got["losses"])
            sim = train_curve(m, stages=L, steps=steps,
                              cfg=BENCH_MODEL.replace(num_layers=L),
                              batch=max(8, L))
            sim_finals[L] = tail(sim["losses"])
            us = got["us_per_step"]
        trend = finals[sizes[-1]] - finals[sizes[0]]
        rows.append({
            "name": f"fig6/spmd_{m}",
            "us_per_call": us,
            "derived": ";".join(
                f"final_L{k}={v:.3f};sim_L{k}={sim_finals[k]:.3f}"
                for k, v in finals.items()
            ) + f";scaling_delta={trend:+.3f}",
        })
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="sim", choices=["sim", "spmd"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep / few steps (CI)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.backend == "spmd":
        emit(run_spmd(quick=not args.full, smoke=args.smoke))
    else:
        emit(run(quick=not args.full))
