"""Fig. 10 (+15): robustness without weight stashing.

Without stashing the forward/backward weight versions differ (incorrect
gradients). Basis rotation stays robust; the baseline degrades. Also runs
PipeMare-style weight prediction (Fig. 15)."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

import jax

from benchmarks.common import BENCH_MODEL, tail
from repro.configs.base import OptimizerConfig
from repro.data import batches
from repro.models import init_model
from repro.optim.base import make_schedule
from repro.optim.factory import build_optimizer
from repro.pipeline.partition import delay_tree
from repro.pipeline.simulate import run_sim_training


def _run(name, steps, no_stash=False, weight_prediction=False):
    cfg = BENCH_MODEL
    ocfg = OptimizerConfig(name=name, learning_rate=3e-3, total_steps=steps,
                           rotation_freq=5)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = build_optimizer(ocfg, params, cfg, num_stages=8)
    kw = {"delays_tree": delay_tree(params, cfg, 8)}
    if weight_prediction:
        kw["weight_prediction"] = True
        kw["schedule"] = make_schedule("cosine", 3e-3, steps, 0.012)
    _, _, losses = run_sim_training(
        cfg, opt, batches(cfg, 8, 32, seed=0), steps=steps, params=params,
        no_stash=no_stash, **kw,
    )
    return losses


def run(quick: bool = True):
    steps = 120 if quick else 400
    rows = []
    for m in ("adam", "basis_rotation"):
        stash = _run(m, steps)
        nostash = _run(m, steps, no_stash=True)
        pred = _run(m, steps, weight_prediction=True)
        rows.append({
            "name": f"fig10/{m}",
            "us_per_call": 0.0,
            "derived": f"stash={tail(stash):.3f};nostash={tail(nostash):.3f};"
                       f"wpred={tail(pred):.3f};"
                       f"degradation={tail(nostash) - tail(stash):+.3f}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
