"""Table 3: delay robustness of preconditioned optimizers at P=8.

Basis alignment (basis rotation ~ SOAP) matters more than preconditioning
per se: Muon (orthogonalised momentum, no eigenbasis alignment) improves on
Adam but trails basis rotation. (Scion is omitted; see DESIGN.md.)"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import slowdown, tail, train_curve


def run(quick: bool = True):
    steps = 150 if quick else 400
    rows = []
    for m, lr in (("adam", 3e-3), ("nesterov", 3e-3), ("muon", 1e-3),
                  ("scion", 1e-3), ("basis_rotation", 3e-3)):
        ref = train_curve(m, stages=1, steps=steps, lr=lr)
        out = train_curve(m, stages=8, steps=steps, lr=lr)
        target = tail(ref["losses"]) * 1.07 + 0.02
        rows.append({
            "name": f"tab3/{m}",
            "us_per_call": out["us_per_step"],
            "derived": f"final_P8={tail(out['losses']):.3f};"
                       f"slowdown={slowdown(out['losses'], ref['losses'], target):.2f}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
