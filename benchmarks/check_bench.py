"""Bench-regression gate: compare a fresh BENCH_*.json against the
committed baseline and fail on large per-row slowdowns.

    python -m benchmarks.check_bench BENCH_kernels_ci.json \
        --baseline benchmarks/BENCH_kernels_smoke.json --max-slowdown 2.5

Rows are matched by name; rows present on only one side are reported but
never fail the gate (renames and new rows must not break CI — the committed
baseline is refreshed in the same PR that renames a row). The threshold is
deliberately loose (2.5x): shared CI runners are noisy and `_time` already
reports a median, so the gate exists to catch order-of-magnitude
regressions (an interpret-mode kernel accidentally enabled, a host sync on
the step path, a donation regression re-introducing per-step copies), not
5% drift.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict


# rows that must exist in every fresh payload: a silent disappearance means
# the comparison stopped being measured, which the name-matched gate alone
# would wave through as "baseline-only". The async data-axis trio is pinned
# because it is the acceptance evidence that the deferred reduction stays on
# the benchmarked path.
REQUIRED_ROWS = (
    "kernels_vs_xla/data_axis_sync",
    "kernels_vs_xla/data_axis_async_d1",
    "kernels_vs_xla/data_axis_async_d2",
)


def _rows_by_name(payload: Dict) -> Dict[str, float]:
    return {
        r["name"]: float(r["us_per_call"])
        for r in payload.get("rows", [])
        if "us_per_call" in r
    }


def compare(new: Dict, baseline: Dict, max_slowdown: float):
    """Returns (failures, report_lines); failures is a list of row names."""
    new_rows, base_rows = _rows_by_name(new), _rows_by_name(baseline)
    common = sorted(set(new_rows) & set(base_rows))
    failures, lines = [], []
    for name in common:
        b, n = base_rows[name], new_rows[name]
        ratio = n / b if b > 0 else float("inf")
        flag = ""
        if ratio > max_slowdown:
            failures.append(name)
            flag = f"  <-- FAIL (> {max_slowdown:.1f}x)"
        lines.append(f"  {name}: {b:.0f}us -> {n:.0f}us ({ratio:.2f}x){flag}")
    for name in sorted(set(base_rows) - set(new_rows)):
        lines.append(f"  {name}: removed (baseline-only, not gated)")
    for name in sorted(set(new_rows) - set(base_rows)):
        lines.append(f"  {name}: new row (no baseline, not gated)")
    if new.get("benchmark") == "kernels_vs_xla":
        for name in REQUIRED_ROWS:
            if name not in new_rows:
                failures.append(name)
                lines.append(f"  {name}: MISSING (required row)  <-- FAIL")
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="fresh BENCH_*.json from this run")
    ap.add_argument("--baseline", default="benchmarks/BENCH_kernels_smoke.json")
    ap.add_argument("--max-slowdown", type=float, default=2.5,
                    help="fail when new/baseline exceeds this per row")
    args = ap.parse_args(argv)

    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures, lines = compare(new, baseline, args.max_slowdown)
    print(f"bench gate: {args.new} vs {args.baseline} "
          f"(max slowdown {args.max_slowdown:.1f}x)")
    print("\n".join(lines))
    if failures:
        print(f"FAIL: {len(failures)} row(s) regressed beyond "
              f"{args.max_slowdown:.1f}x: {', '.join(failures)}")
        return 1
    print(f"OK: {len(lines)} row(s) checked, none beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
