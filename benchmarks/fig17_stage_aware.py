"""Fig. 17 (Appendix I): stage-aware basis-refresh allocation vs uniform vs
the reversed ablation, at the same total refresh budget — runnable on either
backend.

``--backend sim`` (default) runs the virtual-stage simulation; ``--backend
spmd`` runs the same three allocations on the shard_map pipeline runtime
(subprocess with forced host devices), where the per-stage periods live
inside one stacked ``(K, per, m, n)`` leaf via the vectorized refresh mask.

The sim sweep is 2-D: each allocation runs at every data delay in
``DATA_DELAYS`` (0 = pipeline staleness only; D > 0 composes the uniform
staleness of a D-step deferred cross-replica reduction onto every leaf, the
async data axis). The stage-aware allocation renormalises its refresh
budget over the TOTAL per-leaf delay tau + D, so the sweep shows whether
its advantage over uniform survives when the data axis goes asynchronous.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

sys.path.insert(0, "src")

from benchmarks.common import tail, train_curve

ALLOCATIONS = (
    ("uniform", {}),
    ("stage_aware", {"stage_aware": True}),
    ("reversed", {"stage_aware": True, "stage_aware_reversed": True}),
)

SPMD_SCRIPT = r"""
import sys
sys.path.insert(0, "src")
from repro.launch.devices import ensure_host_devices
ensure_host_devices(%(stages)d)
import json, time
import jax
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec, OptimizerConfig
from repro.data import batches
from repro.engine import LoopConfig, SpmdEngine, run_loop
from repro.launch.mesh import make_mesh_compat

cfg = ModelConfig(num_layers=%(stages)d, d_model=32, d_ff=64, vocab_size=64,
                  max_seq_len=64,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
                  pattern=(BlockSpec("attn", "dense"),), scan_layers=False)
K, M, steps = %(stages)d, %(stages)d, %(steps)d
mesh = make_mesh_compat((K, 1), ("stage", "data"))
rows = []
for label, kw in %(allocs)s:
    ocfg = OptimizerConfig(name="basis_rotation", learning_rate=3e-3,
                           total_steps=steps, rotation_freq=5,
                           schedule="constant", **kw)
    engine = SpmdEngine(cfg, ocfg, num_stages=K, num_microbatches=M, mesh=mesh)
    state = engine.init_state(key=jax.random.PRNGKey(0))
    data = batches(cfg, M * 2, 16, seed=0)
    state, first = run_loop(engine, data, LoopConfig(steps=1), state=state)  # compile
    t0 = time.perf_counter()
    state, losses = run_loop(engine, data, LoopConfig(steps=steps), state=state,
                             start_step=1)
    dt = time.perf_counter() - t0
    losses = first + losses
    rows.append({"label": label, "us_per_step": 1e6 * dt / (steps - 1),
                 "final": sum(losses[-5:]) / 5})
print(json.dumps(rows))
"""


def spmd_rows(quick: bool = True):
    stages = 4 if quick else 8
    steps = 10 if quick else 120
    script = SPMD_SCRIPT % {
        "stages": stages, "steps": steps, "allocs": repr(ALLOCATIONS),
    }
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=1800,
    )
    if out.returncode != 0:
        raise RuntimeError(f"fig17 spmd subprocess failed: {out.stderr[-2000:]}")
    rows = []
    for r in json.loads(out.stdout.strip().splitlines()[-1]):
        rows.append({
            "name": f"fig17/spmd_{r['label']}",
            "us_per_call": r["us_per_step"],
            "derived": f"K={stages};final={r['final']:.3f}",
        })
    return rows


# second sweep axis: data-axis staleness of the deferred reduction
DATA_DELAYS = (0, 1, 2)


def sim_rows(quick: bool = True, smoke: bool = False):
    stages, steps = (4, 20) if smoke else (8, 120 if quick else 400)
    delays = DATA_DELAYS[:2] if smoke else DATA_DELAYS
    rows = []
    for data_delay in delays:
        for label, kw in ALLOCATIONS:
            out = train_curve("basis_rotation", stages=stages, steps=steps,
                              rotation_freq=10, data_delay=data_delay, **kw)
            # D=0 keeps the original row names so the committed BENCH
            # baselines and any trend tooling keep matching
            suffix = f"_dd{data_delay}" if data_delay else ""
            rows.append({"name": f"fig17/sim_{label}{suffix}",
                         "us_per_call": out["us_per_step"],
                         "derived": (f"data_delay={data_delay};"
                                     f"final={tail(out['losses']):.3f}")})
    return rows


def run(quick: bool = True):
    return sim_rows(quick=quick)


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="sim", choices=["sim", "spmd"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few steps (CI)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.backend == "spmd":
        emit(spmd_rows(quick=args.smoke or not args.full))
    else:
        emit(sim_rows(quick=not args.full, smoke=args.smoke))
