"""Fig. 9: computational efficiency of basis rotation.

(a) wall-clock per step vs baselines (us_per_call column);
(b) basis-update frequency sweep (performance degrades only mildly);
(c) stage-aware vs uniform vs reversed allocation under the same budget."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import tail, train_curve


def run(quick: bool = True):
    steps = 150 if quick else 400
    rows = []
    # (a) GPU-hours proxy: us/step at P=8
    for m in ("adam", "nesterov", "basis_rotation"):
        out = train_curve(m, stages=8, steps=steps)
        rows.append({"name": f"fig9a/{m}", "us_per_call": out["us_per_step"],
                     "derived": f"final={tail(out['losses']):.3f}"})
    # (b) frequency sweep
    for freq in (2, 10, 50):
        out = train_curve("basis_rotation", stages=8, steps=steps, rotation_freq=freq)
        rows.append({"name": f"fig9b/freq{freq}", "us_per_call": out["us_per_step"],
                     "derived": f"final={tail(out['losses']):.3f}"})
    # (c) stage-aware allocation (+ reversed ablation, Fig. 17)
    uni = train_curve("basis_rotation", stages=8, steps=steps, rotation_freq=10)
    sa = train_curve("basis_rotation", stages=8, steps=steps, rotation_freq=10,
                     stage_aware=True)
    rev = train_curve("basis_rotation", stages=8, steps=steps, rotation_freq=10,
                      stage_aware=True, stage_aware_reversed=True)
    rows.append({"name": "fig9c/uniform", "us_per_call": uni["us_per_step"],
                 "derived": f"final={tail(uni['losses']):.3f}"})
    rows.append({"name": "fig9c/stage_aware", "us_per_call": sa["us_per_step"],
                 "derived": f"final={tail(sa['losses']):.3f}"})
    rows.append({"name": "fig9c/reversed", "us_per_call": rev["us_per_step"],
                 "derived": f"final={tail(rev['losses']):.3f}"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
