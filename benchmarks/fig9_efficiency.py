"""Fig. 9: computational efficiency of basis rotation.

(a) wall-clock per step vs baselines (us_per_call column);
(b) basis-update frequency sweep (performance degrades only mildly);
(c) stage-aware vs uniform vs reversed allocation under the same budget;
(d) SPMD schedule comparison — fill-drain vs 1F1B step time on the real
    shard_map runtime (subprocess with forced host devices)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

sys.path.insert(0, "src")

from benchmarks.common import tail, train_curve

SPMD_TIMING_SCRIPT = r"""
import sys
sys.path.insert(0, "src")
from repro.launch.devices import ensure_host_devices
ensure_host_devices(%(stages)d)
import json, time
import jax
from repro.configs.base import ModelConfig, AttentionConfig, BlockSpec, OptimizerConfig
from repro.data import batches
from repro.engine import LoopConfig, SpmdEngine, run_loop
from repro.launch.mesh import make_mesh_compat

cfg = ModelConfig(num_layers=%(stages)d, d_model=32, d_ff=64, vocab_size=64,
                  max_seq_len=64,
                  attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
                  pattern=(BlockSpec("attn", "dense"),), scan_layers=False)
K, M, steps = %(stages)d, %(microbatches)d, %(steps)d
mesh = make_mesh_compat((K, 1), ("stage", "data"))
rows = []
for sched in %(schedules)s:
    ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=steps,
                           schedule="constant")
    engine = SpmdEngine(cfg, ocfg, num_stages=K, num_microbatches=M, mesh=mesh,
                        schedule=sched)
    state = engine.init_state(key=jax.random.PRNGKey(0))
    data = batches(cfg, M * 2, 16, seed=0)
    state, _ = run_loop(engine, data, LoopConfig(steps=1), state=state)  # compile
    t0 = time.perf_counter()
    state, losses = run_loop(engine, data, LoopConfig(steps=steps), state=state,
                             start_step=1)
    dt = time.perf_counter() - t0
    rows.append({"schedule": sched, "us_per_step": 1e6 * dt / (steps - 1),
                 "final": losses[-1]})
print(json.dumps(rows))
"""


def spmd_schedule_rows(quick: bool = True, schedules=("fill_drain", "1f1b")):
    """Time the shard_map runtime under each schedule (fig9d)."""
    stages, microbatches = (4, 8) if quick else (8, 16)
    steps = 6 if quick else 20
    script = SPMD_TIMING_SCRIPT % {
        "stages": stages, "microbatches": microbatches, "steps": steps,
        "schedules": repr(tuple(schedules)),
    }
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=1800,
    )
    if out.returncode != 0:
        raise RuntimeError(f"spmd timing subprocess failed: {out.stderr[-2000:]}")
    rows = []
    for r in json.loads(out.stdout.strip().splitlines()[-1]):
        rows.append({
            "name": f"fig9d/spmd_{r['schedule']}",
            "us_per_call": r["us_per_step"],
            "derived": f"K={stages};M={microbatches};final={r['final']:.3f}",
        })
    return rows


def run(quick: bool = True):
    steps = 150 if quick else 400
    rows = []
    # (a) GPU-hours proxy: us/step at P=8
    for m in ("adam", "nesterov", "basis_rotation"):
        out = train_curve(m, stages=8, steps=steps)
        rows.append({"name": f"fig9a/{m}", "us_per_call": out["us_per_step"],
                     "derived": f"final={tail(out['losses']):.3f}"})
    # (b) frequency sweep
    for freq in (2, 10, 50):
        out = train_curve("basis_rotation", stages=8, steps=steps, rotation_freq=freq)
        rows.append({"name": f"fig9b/freq{freq}", "us_per_call": out["us_per_step"],
                     "derived": f"final={tail(out['losses']):.3f}"})
    # (c) stage-aware allocation (+ reversed ablation, Fig. 17)
    uni = train_curve("basis_rotation", stages=8, steps=steps, rotation_freq=10)
    sa = train_curve("basis_rotation", stages=8, steps=steps, rotation_freq=10,
                     stage_aware=True)
    rev = train_curve("basis_rotation", stages=8, steps=steps, rotation_freq=10,
                      stage_aware=True, stage_aware_reversed=True)
    rows.append({"name": "fig9c/uniform", "us_per_call": uni["us_per_step"],
                 "derived": f"final={tail(uni['losses']):.3f}"})
    rows.append({"name": "fig9c/stage_aware", "us_per_call": sa["us_per_step"],
                 "derived": f"final={tail(sa['losses']):.3f}"})
    rows.append({"name": "fig9c/reversed", "us_per_call": rev["us_per_step"],
                 "derived": f"final={tail(rev['losses']):.3f}"})
    # (d) SPMD runtime: step-time of fill-drain vs 1F1B on forced host devices
    rows.extend(spmd_schedule_rows(quick=quick))
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--spmd-smoke", action="store_true",
                    help="only the 1F1B schedule point at tiny shapes (CI)")
    args = ap.parse_args()
    if args.spmd_smoke:
        emit(spmd_schedule_rows(quick=True, schedules=("1f1b",)))
    else:
        emit(run())
