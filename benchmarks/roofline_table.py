"""Roofline summary (deliverable g): reads the dry-run JSONL artifacts and
emits one row per (arch x shape x mesh) with the three terms and bottleneck."""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, "src")

FILES = [
    "experiments/dryrun_singlepod.jsonl",
    "experiments/dryrun_multipod.jsonl",
    "experiments/dryrun_perf.jsonl",
]


def run(quick: bool = True):
    rows = []
    seen = set()
    for path in FILES:
        if not os.path.exists(path):
            continue
        for line in open(path):
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("multi_pod"), r.get("variant", ""))
            if key in seen:
                continue
            seen.add(key)
            pod = "2pod" if r.get("multi_pod") else "1pod"
            name = f"roofline/{r.get('arch')}/{r.get('shape')}/{pod}"
            if r.get("variant"):
                name += f"/{r['variant']}"
            if r.get("status") != "ok":
                rows.append({"name": name, "us_per_call": 0.0,
                             "derived": f"status={r.get('status')};{r.get('reason', r.get('error', ''))[:60]}"})
                continue
            rows.append({
                "name": name,
                "us_per_call": 1e6 * max(r["compute_s"], r["memory_s"], r["collective_s"]),
                "derived": (
                    f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
                    f"collective_s={r['collective_s']:.4f};bottleneck={r['bottleneck']};"
                    f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
                    f"peak_gb={r.get('peak_bytes_per_device', 0) / 1e9:.1f}"
                ),
            })
    if not rows:
        rows.append({"name": "roofline/missing", "us_per_call": 0.0,
                     "derived": "run python -m repro.launch.dryrun --all first"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
