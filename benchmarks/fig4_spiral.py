"""Fig. 4: spiral loss landscape — slowdown ratio T_delay/T_no-delay at
aligned vs misaligned points along the trajectory.

f(r, theta) = r^2 + (20 sin(4r - theta) + 1)^2 in polar coordinates;
the Hessian eigenbasis rotates along the spiral, so alignment with the
coordinate axes varies with the angle. We measure the iterations to traverse
a fixed angular interval with and without delay tau=1 from several starting
angles and report the min/max slowdown (aligned vs misaligned regions).
"""
from __future__ import annotations

import math
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.optim import adam, constant_schedule
from repro.optim.base import apply_updates
from repro.pipeline.delay import delayed_optimizer


def spiral_loss(w):
    x, y = w[0], w[1]
    r = jnp.sqrt(x * x + y * y + 1e-9)
    theta = jnp.arctan2(y, x)
    return r**2 + (20.0 * jnp.sin(4.0 * r - theta) + 1.0) ** 2


GRAD = jax.grad(spiral_loss)
ANGLE_STEP = math.radians(3.0)


def _iters_to_advance(theta0: float, tau: int, direction: float,
                      max_iters: int = 3000) -> int:
    """Iterations until the iterate advances ANGLE_STEP in `direction`
    (signed — oscillating back and forth does not count as progress)."""
    r0 = (theta0 + 20.0) / 4.0  # start near the sin-valley: 4r - theta = 0
    w = jnp.asarray([r0 * math.cos(theta0), r0 * math.sin(theta0)])
    base = adam(constant_schedule(0.1), beta1=0.0, beta2=0.9)
    opt = delayed_optimizer(base, [tau]) if tau else base
    params = {"w": w}
    state = opt.init(params)
    start_angle = math.atan2(float(w[1]), float(w[0]))
    for t in range(max_iters):
        ang = math.atan2(float(params["w"][1]), float(params["w"][0]))
        d = (ang - start_angle + math.pi) % (2 * math.pi) - math.pi
        if direction * d >= ANGLE_STEP:
            return t + 1
        g = {"w": GRAD(params["w"])}
        u, state = opt.update(g, state, params, jnp.int32(t))
        params = apply_updates(params, u)
    return max_iters


def _natural_direction(theta0: float, steps: int = 200) -> float:
    """Sign of the no-delay trajectory's net angular drift."""
    r0 = (theta0 + 20.0) / 4.0
    w = jnp.asarray([r0 * math.cos(theta0), r0 * math.sin(theta0)])
    opt = adam(constant_schedule(0.1), beta1=0.0, beta2=0.9)
    params = {"w": w}
    state = opt.init(params)
    start_angle = math.atan2(float(w[1]), float(w[0]))
    for t in range(steps):
        g = {"w": GRAD(params["w"])}
        u, state = opt.update(g, state, params, jnp.int32(t))
        params = apply_updates(params, u)
    ang = math.atan2(float(params["w"][1]), float(params["w"][0]))
    d = (ang - start_angle + math.pi) % (2 * math.pi) - math.pi
    return 1.0 if d >= 0 else -1.0


def run(quick: bool = True):
    angles = [0.0, 0.8, 1.6, 2.4, 3.2, 4.0] if quick else [i * 0.4 for i in range(16)]
    t0 = time.perf_counter()
    ratios = []
    for th in angles:
        direction = _natural_direction(th)
        n0 = _iters_to_advance(th, tau=0, direction=direction)
        n1 = _iters_to_advance(th, tau=1, direction=direction)
        ratios.append(n1 / max(n0, 1))
    dt = (time.perf_counter() - t0) * 1e6 / len(angles)
    return [{
        "name": "fig4/spiral_slowdown",
        "us_per_call": dt,
        "derived": f"min_ratio={min(ratios):.2f};max_ratio={max(ratios):.2f};"
                   f"spread={max(ratios) / max(min(ratios), 1e-9):.2f}",
    }]


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
