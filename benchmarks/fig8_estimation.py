"""Fig. 8 / Table 8a: eigenbasis-estimation strategy comparison.

All four (S x G) strategies at P=8 vs the PipeDream-LR baseline; derived:
final loss + slowdown vs the P=1 reference (lower = more delay-robust)."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import slowdown, tail, train_curve

STRATS = [("1st", "unilateral"), ("1st", "bilateral"),
          ("2nd", "unilateral"), ("2nd", "bilateral")]


def run(quick: bool = True):
    steps = 150 if quick else 400
    ref = train_curve("adam", stages=1, steps=steps)
    target = tail(ref["losses"]) * 1.07 + 0.02
    rows = []
    base = train_curve("pipedream_lr", stages=8, steps=steps)
    rows.append({
        "name": "fig8/pipedream_lr",
        "us_per_call": base["us_per_step"],
        "derived": f"final={tail(base['losses']):.3f};"
                   f"slowdown={slowdown(base['losses'], ref['losses'], target):.2f}",
    })
    for src, geom in STRATS:
        out = train_curve("basis_rotation", stages=8, steps=steps,
                          rotation_source=src, rotation_geometry=geom)
        rows.append({
            "name": f"fig8/br_{src}_{geom[:3]}",
            "us_per_call": out["us_per_step"],
            "derived": f"final={tail(out['losses']):.3f};"
                       f"slowdown={slowdown(out['losses'], ref['losses'], target):.2f}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
