"""Pallas kernel path vs XLA baseline for the basis-rotation update.

Times one full `basis_rotation_adam` update on a stage-stacked
``(K, per, m, n)`` leaf with ``use_kernels`` on/off, plus the fused
Adam-scale kernel against its pure-jnp reference in isolation. Off-TPU the
kernels run in interpret mode — the comparison there validates wiring and
correctness, not speed (Mosaic compilation only exists on TPU); on a TPU
host the same rows measure the real kernel path.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / iters


def optimizer_rows(K: int, per: int, dim: int):
    from repro.core.basis_rotation import basis_rotation_adam
    from repro.optim.base import constant_schedule

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (K, per, dim, dim))}
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (K, per, dim, dim))}
    rows = []
    for use_kernels in (False, True):
        opt = basis_rotation_adam(
            constant_schedule(1e-3), freq=1, use_kernels=use_kernels
        )
        s = opt.init(params)

        @jax.jit
        def step(g, s):
            return opt.update(g, s, params, jnp.int32(1))

        us = _time(step, g, s)
        label = "kernels" if use_kernels else "xla"
        rows.append({
            "name": f"kernels_vs_xla/rotation_update_{label}",
            "us_per_call": us,
            "derived": f"K={K};per={per};dim={dim}",
        })
    return rows


def adam_scale_rows(shape):
    from repro.kernels import ops, ref

    g = jax.random.normal(jax.random.PRNGKey(0), shape)
    m = jax.random.normal(jax.random.PRNGKey(1), shape)
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), shape)) + 0.1

    kfn = jax.jit(lambda g, m, v: ops.adam_scale(g, m, v, 0.999, 1e-8, 0.9, 0.1))
    rfn = jax.jit(lambda g, m, v: ref.fused_adam_scale_ref(g, m, v, 0.999, 1e-8, 0.9, 0.1))
    us_k = _time(kfn, g, m, v)
    us_r = _time(rfn, g, m, v)
    sk, vk = kfn(g, m, v)
    sr, vr = rfn(g, m, v)
    err = max(float(jnp.max(jnp.abs(sk - sr))), float(jnp.max(jnp.abs(vk - vr))))
    return [
        {"name": "kernels_vs_xla/fused_adam_kernel", "us_per_call": us_k,
         "derived": f"shape={'x'.join(map(str, shape))};maxerr={err:.1e}"},
        {"name": "kernels_vs_xla/fused_adam_xla", "us_per_call": us_r,
         "derived": f"shape={'x'.join(map(str, shape))}"},
    ]


def run(quick: bool = True):
    if quick:
        return optimizer_rows(2, 1, 32) + adam_scale_rows((64, 64))
    return optimizer_rows(4, 2, 256) + adam_scale_rows((1024, 1024))


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI: interpret mode on CPU)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    emit(run(quick=args.smoke or not args.full))
