"""Pallas kernel path vs XLA baseline: optimizer update, fused Adam scale,
flash attention forward/backward, and the full train step.

Times one full `basis_rotation_adam` update on a stage-stacked
``(K, per, m, n)`` leaf with ``use_kernels`` on/off, the fused Adam-scale
kernel against its pure-jnp reference, the flash-attention kernel (forward
AND its custom-vjp backward) against `kernels/ref.py::flash_attention_ref`
under `jax.grad`, and a complete SpmdEngine step with the kernel path and
precision policy on/off — plus a step-time/HBM roofline row from the
compiled step's cost analysis, and the sync-vs-async data-axis step-time
rows (`data_axis_rows`: the cross-replica gradient all-reduce on vs off
the step critical path at data delays 1 and 2). Off-TPU the kernels run in interpret mode —
the comparison there validates wiring and correctness, not speed (Mosaic
compilation only exists on TPU); on a TPU host the same rows measure the
real kernel path.

``--bench-out BENCH_foo.json`` additionally runs the pinned 2-stage smoke
training (1F1B, ``use_kernels``, bf16) and writes the perf-trajectory
artifact (rows + step time + final loss) that CI uploads so later PRs are
tracked against it; the committed baseline lives at
``benchmarks/BENCH_kernels_smoke.json``.
"""
from __future__ import annotations

import json
import statistics
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 10, warmup: int = 2):
    """Median microseconds per call.

    The first call compiles; the next ``warmup`` calls absorb allocator and
    cache effects; then every timed call is synchronised individually with
    `block_until_ready` and the MEDIAN over >= 10 samples is reported — a
    mean over 3 unsynchronised calls (the old scheme) let one GC pause or
    compile-cache miss swing the committed baseline by 2x.
    """
    for _ in range(max(warmup, 1) + 1):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return 1e6 * statistics.median(samples)


def _time_carry(fn, carry, iters: int = 10, warmup: int = 2):
    """`_time` for self-feeding steps: `fn(carry) -> carry`.

    A DONATED train step consumes its input buffers, so timing it by
    replaying the same arguments (the old scheme) would die on the second
    call; threading the output back as the next input is also the honest
    measurement — it is exactly what `run_loop` does.
    """
    for _ in range(max(warmup, 1) + 1):
        carry = fn(carry)
        jax.block_until_ready(carry)
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        carry = fn(carry)
        jax.block_until_ready(carry)
        samples.append(time.perf_counter() - t0)
    return 1e6 * statistics.median(samples)


def optimizer_rows(K: int, per: int, dim: int):
    from repro.core.basis_rotation import basis_rotation_adam
    from repro.optim.base import constant_schedule

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (K, per, dim, dim))}
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (K, per, dim, dim))}
    rows = []
    for use_kernels in (False, True):
        opt = basis_rotation_adam(
            constant_schedule(1e-3), freq=1, use_kernels=use_kernels
        )
        s = opt.init(params)

        @jax.jit
        def step(g, s):
            return opt.update(g, s, params, jnp.int32(1))

        us = _time(step, g, s)
        label = "kernels" if use_kernels else "xla"
        rows.append({
            "name": f"kernels_vs_xla/rotation_update_{label}",
            "us_per_call": us,
            "derived": f"K={K};per={per};dim={dim}",
        })
    return rows


def adam_scale_rows(shape):
    from repro.kernels import ops, ref

    g = jax.random.normal(jax.random.PRNGKey(0), shape)
    m = jax.random.normal(jax.random.PRNGKey(1), shape)
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), shape)) + 0.1

    kfn = jax.jit(lambda g, m, v: ops.adam_scale(g, m, v, 0.999, 1e-8, 0.9, 0.1))
    rfn = jax.jit(lambda g, m, v: ref.fused_adam_scale_ref(g, m, v, 0.999, 1e-8, 0.9, 0.1))
    us_k = _time(kfn, g, m, v)
    us_r = _time(rfn, g, m, v)
    sk, vk = kfn(g, m, v)
    sr, vr = rfn(g, m, v)
    err = max(float(jnp.max(jnp.abs(sk - sr))), float(jnp.max(jnp.abs(vk - vr))))
    return [
        {"name": "kernels_vs_xla/fused_adam_kernel", "us_per_call": us_k,
         "derived": f"shape={'x'.join(map(str, shape))};maxerr={err:.1e}"},
        {"name": "kernels_vs_xla/fused_adam_xla", "us_per_call": us_r,
         "derived": f"shape={'x'.join(map(str, shape))}"},
    ]


def attention_rows(B: int, H: int, S: int, dh: int, window=None):
    """Flash kernel (autotuned AND default-block plans) vs XLA reference:
    forward and `jax.grad` backward.

    The tuned plan comes straight from `repro.tune` (`write=False` — the
    benchmark never mutates the persistent cache): the measured backend on
    TPU, the analytical cost model off-TPU. The default plan is the
    pre-tuner hardcoded 128-block tiling, kept as a row so the BENCH
    trajectory records the tuning win at every shape.
    """
    from repro import tune
    from repro.kernels import ops, ref

    plan = tune.tune_flash(S, dh, batch_heads=B * H, write=False)
    shape = (B, H, S, dh)
    q = jax.random.normal(jax.random.PRNGKey(0), shape)
    k = jax.random.normal(jax.random.PRNGKey(1), shape)
    v = jax.random.normal(jax.random.PRNGKey(2), shape)
    do = jax.random.normal(jax.random.PRNGKey(3), shape)

    ktuned = jax.jit(lambda q, k, v: ops.attention(
        q, k, v, window=window,
        block_q=plan["block_q"], block_k=plan["block_k"],
    ))
    kdefault = jax.jit(lambda q, k, v: ops.attention(
        q, k, v, window=window, block_q=128, block_k=128,
    ))
    rfwd = jax.jit(
        lambda q, k, v: ref.flash_attention_ref(q, k, v, window=window)
    )
    err_f = float(jnp.max(jnp.abs(ktuned(q, k, v) - rfwd(q, k, v))))

    def _gradfn(fwd):
        return jax.jit(jax.grad(
            lambda q, k, v: jnp.sum(fwd(q, k, v) * do), argnums=(0, 1, 2)
        ))

    kbwd_t, kbwd_d, rbwd = _gradfn(ktuned), _gradfn(kdefault), _gradfn(rfwd)
    err_b = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(kbwd_t(q, k, v), rbwd(q, k, v))
    )
    dims = f"B={B};H={H};S={S};dh={dh}"
    blocks = f"bq={plan['block_q']};bk={plan['block_k']}"
    return [
        {"name": "kernels_vs_xla/attention_fwd_kernel_tuned",
         "us_per_call": _time(ktuned, q, k, v),
         "derived": f"{dims};{blocks};maxerr={err_f:.1e}"},
        {"name": "kernels_vs_xla/attention_fwd_kernel_default",
         "us_per_call": _time(kdefault, q, k, v),
         "derived": f"{dims};bq=128;bk=128"},
        {"name": "kernels_vs_xla/attention_fwd_xla",
         "us_per_call": _time(rfwd, q, k, v), "derived": dims},
        {"name": "kernels_vs_xla/attention_bwd_kernel_tuned",
         "us_per_call": _time(kbwd_t, q, k, v),
         "derived": f"{dims};{blocks};maxerr={err_b:.1e}"},
        {"name": "kernels_vs_xla/attention_bwd_kernel_default",
         "us_per_call": _time(kbwd_d, q, k, v),
         "derived": f"{dims};bq=128;bk=128"},
        {"name": "kernels_vs_xla/attention_bwd_xla",
         "us_per_call": _time(rbwd, q, k, v), "derived": dims},
    ]


# full-step / roofline model: single-stage engine so the benchmark runs on
# one device in-process; the pipeline dimension is measured by the spmd
# curve benchmarks, not here
_STEP_CONFIGS = (
    ("xla_f32", False, "f32"),
    ("kernels_f32", True, "f32"),
    ("kernels_bf16", True, "bf16"),
)


def _step_engine(
    num_layers: int, use_kernels: bool, precision: str, donate="auto"
):
    from repro.configs.base import (
        AttentionConfig, BlockSpec, ModelConfig, OptimizerConfig,
    )
    from repro.engine.spmd import SpmdEngine
    from repro.launch.topology import Topology

    cfg = ModelConfig(
        name="bench_step", num_layers=num_layers, d_model=64, d_ff=256,
        vocab_size=128, max_seq_len=64,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        pattern=(BlockSpec("attn", "dense"),), scan_layers=False,
    )
    ocfg = OptimizerConfig(name="adam", learning_rate=1e-3, total_steps=8,
                           schedule="constant")
    return SpmdEngine(
        cfg, ocfg, num_stages=1, num_microbatches=1,
        topology=Topology(stages=1, data=1),
        use_kernels=use_kernels, precision=precision, donate=donate,
    )


def _time_full_step(engine, batch: int, seq: int):
    """Median step time with the state threaded through like `run_loop`
    does (mandatory for the donated engine; fair for both)."""
    state = engine.init_state(key=jax.random.PRNGKey(0))
    tok = jax.random.randint(
        jax.random.PRNGKey(1), (1, batch, seq), 0, engine.cfg.vocab_size
    )
    batch_d = {"tokens": tok, "labels": tok}
    stacked, shared = state.params

    def step(carry):
        stacked, shared, opt_state = carry
        out = engine._jit_step(stacked, shared, opt_state, batch_d,
                               jnp.int32(0))
        return out[:3]

    return _time_carry(step, (stacked, shared, state.opt_state))


def full_step_rows(num_layers: int, batch: int, seq: int):
    """One complete train step (grads + clip + Adam) per kernel/precision
    configuration with the platform-default donation setting, plus the
    roofline row for the kernel+bf16 step.

    On accelerators (where `SpmdEngine(donate="auto")` resolves ON) each
    config additionally gets an explicit `_donate`/`_nodonate` pair so the
    BENCH trajectory records the per-step copy cost donation removes. The
    pair is NOT emitted on CPU: there donation is default-off because
    in-place aliasing serializes the XLA:CPU thunk schedule (~10-20% slower
    step, DESIGN.md §11 known limits), and a committed slower-by-design row
    would only add noise to the regression gate."""
    import jax

    donation_default_on = jax.default_backend() in ("tpu", "gpu")
    rows = []
    for label, use_kernels, precision in _STEP_CONFIGS:
        engine = _step_engine(num_layers, use_kernels, precision)
        us = _time_full_step(engine, batch, seq)
        rows.append({
            "name": f"kernels_vs_xla/full_step_{label}",
            "us_per_call": us,
            "derived": (
                f"layers={num_layers};batch={batch};seq={seq};"
                f"donate={int(engine.donate)}"
            ),
        })
        if label == "kernels_bf16":
            rows.append(roofline_row(engine, batch, seq))
        if donation_default_on:
            for donate in (True, False):
                eng = _step_engine(num_layers, use_kernels, precision,
                                   donate=donate)
                suffix = "_donate" if donate else "_nodonate"
                rows.append({
                    "name": f"kernels_vs_xla/full_step_{label}{suffix}",
                    "us_per_call": _time_full_step(eng, batch, seq),
                    "derived": (
                        f"layers={num_layers};batch={batch};seq={seq};"
                        f"donate={int(donate)}"
                    ),
                })
    return rows


def roofline_row(engine, batch: int, seq: int):
    """TPU-v5e roofline terms of the compiled kernel+bf16 step.

    On a CPU host the FLOP/byte counts come from the CPU-compiled module —
    the row tracks the cost *structure* (bottleneck term, HBM traffic);
    absolute times are only meaningful on a TPU host.
    """
    from repro.launch.roofline import dense_model_flops, roofline_from_compiled
    from repro.models import init_model, param_count

    compiled = engine.compiled_step(seq_len=seq, microbatch_size=batch)
    n_params = param_count(
        init_model(jax.random.PRNGKey(0), engine.cfg)
    )
    r = roofline_from_compiled(
        compiled,
        model_flops=dense_model_flops(n_params, tokens=batch * seq),
    )
    return {
        "name": "kernels_vs_xla/roofline_step_kernels_bf16",
        "us_per_call": 1e6 * r.step_time_s,
        "derived": (
            f"bottleneck={r.bottleneck};hbm_mb={r.hbm_bytes / 1e6:.1f};"
            f"gflops={r.flops / 1e9:.2f};useful={r.useful_flops_ratio:.2f}"
        ),
    }


# sync vs async data axis: the same 2-stage, 2-replica 1F1B training with
# the cross-replica gradient all-reduce on the step critical path (sync) vs
# deferred D steps through the engine FIFO (async). On CPU hosts the
# absolute win is modest (gloo-free intra-process collectives are cheap);
# the rows exist so the BENCH trajectory records the step-time relation and
# a TPU refresh measures the real overlap win.
DATA_AXIS_RUN = {
    "name": "adam", "stages": 2, "num_layers": 4, "batch": 8, "seq": 32,
    "lr": 3e-3, "seed": 0, "schedule": "1f1b", "data_par": 2,
}

DATA_AXIS_VARIANTS = (
    ("sync", {}),
    ("async_d1", {"data_async": True, "data_delay": 1}),
    ("async_d2", {"data_async": True, "data_delay": 2}),
)


def data_axis_rows(quick: bool):
    from benchmarks.common import spmd_train_curves, tail

    steps = 8 if quick else 30
    runs = [{**DATA_AXIS_RUN, "steps": steps, **kw}
            for _, kw in DATA_AXIS_VARIANTS]
    res = spmd_train_curves(runs)
    rows = []
    for (label, kw), r in zip(DATA_AXIS_VARIANTS, res):
        rows.append({
            "name": f"kernels_vs_xla/data_axis_{label}",
            "us_per_call": r["us_per_step"],
            "derived": (
                f"stages=2;data_par=2;steps={steps};"
                f"delay={kw.get('data_delay', 0)};"
                f"final={tail(r['losses'], 3):.3f}"
            ),
        })
    return rows


# pinned perf-trajectory config: 2-stage 1F1B with the full kernel + bf16
# path — the BENCH artifact tracks (step_time_us, final_loss) across PRs
BENCH_RUN = {
    "name": "adam", "stages": 2, "num_layers": 4, "batch": 8, "seq": 32,
    "lr": 3e-3, "seed": 0, "schedule": "1f1b", "use_kernels": True,
    "precision": "bf16",
}


def bench_payload(rows, quick: bool):
    """Assemble the BENCH_*.json perf-trajectory artifact."""
    from benchmarks.common import spmd_train_curves, tail

    run = {**BENCH_RUN, "steps": 10 if quick else 40}
    (res,) = spmd_train_curves([run])
    return {
        "schema": "repro-bench/v1",
        "benchmark": "kernels_vs_xla",
        "created": time.strftime("%Y-%m-%d"),
        "quick": quick,
        "rows": rows,
        "trajectory": {
            "config": run,
            "step_time_us": res["us_per_step"],
            "final_loss": tail(res["losses"], 3),
            "losses": res["losses"],
        },
    }


def run(quick: bool = True):
    if quick:
        return (
            optimizer_rows(2, 1, 32) + adam_scale_rows((64, 64))
            + attention_rows(1, 2, 256, 16, window=32)
            + full_step_rows(num_layers=2, batch=4, seq=32)
            + data_axis_rows(quick=True)
        )
    return (
        optimizer_rows(4, 2, 256) + adam_scale_rows((1024, 1024))
        + attention_rows(2, 4, 512, 64, window=128)
        + full_step_rows(num_layers=8, batch=8, seq=64)
        + data_axis_rows(quick=False)
    )


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI: interpret mode on CPU)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--bench-out", default=None, metavar="BENCH_*.json",
                    help="also run the pinned 2-stage smoke training and "
                         "write the perf-trajectory JSON artifact here")
    args = ap.parse_args()
    rows = run(quick=args.smoke or not args.full)
    emit(rows)
    if args.bench_out:
        payload = bench_payload(rows, quick=args.smoke or not args.full)
        with open(args.bench_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"bench trajectory -> {args.bench_out} "
              f"(step {payload['trajectory']['step_time_us']:.0f}us, "
              f"final loss {payload['trajectory']['final_loss']:.4f})")
