"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` runs the longer versions;
the default quick mode keeps the whole suite CPU-friendly.
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

MODULES = [
    "fig3_quadratic",
    "fig4_spiral",
    "fig5_stages",
    "fig6_scaling",
    "fig8_estimation",
    "fig9_efficiency",
    "fig10_stashing",
    "fig11_alignment",
    "fig17_stage_aware",
    "fig19_dc",
    "fig21_moe",
    "tab2_memory",
    "tab3_preconditioned",
    "roofline_table",
    "kernels_vs_xla",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated module filter")
    args = ap.parse_args()
    mods = MODULES if not args.only else [m for m in MODULES if m in args.only.split(",")]

    print("name,us_per_call,derived")
    for mod_name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run(quick=not args.full)
            for r in rows:
                print(f"{r['name']},{r.get('us_per_call', 0):.1f},{r.get('derived', '')}",
                      flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{mod_name}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)
        print(f"# {mod_name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
