"""Fig. 21: generalization to MoE — basis rotation applied per-expert on a
nanoMoE-style model (4 experts, top-2) under P=4 async pipelining."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import BENCH_MOE, slowdown, tail, train_curve


def run(quick: bool = True):
    steps = 120 if quick else 400
    rows = []
    ref = train_curve("adam", stages=1, steps=steps, cfg=BENCH_MOE)
    target = tail(ref["losses"]) * 1.07 + 0.02
    for m in ("adam", "pipedream_lr", "basis_rotation"):
        out = train_curve(m, stages=4, steps=steps, cfg=BENCH_MOE)
        rows.append({
            "name": f"fig21/{m}",
            "us_per_call": out["us_per_step"],
            "derived": f"final={tail(out['losses']):.3f};"
                       f"slowdown={slowdown(out['losses'], ref['losses'], target):.2f}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
