"""Fig. 19: Delay Compensation (Zheng et al. 2017) baseline — DC fails to
address large delays and tracks vanilla PipeDream."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import tail, train_curve


def run(quick: bool = True):
    steps = 120 if quick else 400
    rows = []
    base = train_curve("adam", stages=8, steps=steps)
    rows.append({"name": "fig19/pipedream", "us_per_call": base["us_per_step"],
                 "derived": f"final={tail(base['losses']):.3f}"})
    for lam in (0.04, 0.1, 0.5, 1.0):
        out = train_curve("delay_compensation", stages=8, steps=steps, dc_lambda=lam)
        rows.append({
            "name": f"fig19/dc_lambda{lam}",
            "us_per_call": out["us_per_step"],
            "derived": f"final={tail(out['losses']):.3f};"
                       f"vs_pipedream={tail(out['losses']) - tail(base['losses']):+.3f}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
